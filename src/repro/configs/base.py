"""Config system: one dataclass covers all ten assigned architectures.

Every architecture file in this package instantiates `ModelConfig` with the
exact published numbers and registers it.  `reduced()` derives the tiny
same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# families: dense | moe | ssm | hybrid | encdec | vlm
# block kinds (hybrid layouts): 'attn' | 'mamba' | 'rwkv'


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int            # per-expert FFN hidden size
    n_shared_experts: int = 0   # always-on experts (Kimi K2 style)
    dense_residual: bool = False  # dense FFN in parallel with MoE (Arctic)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2
    # which layers are MoE: every `every`-th layer starting at `first`
    first_moe_layer: int = 0
    moe_every: int = 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    head_dim: Optional[int] = None      # default d_model // n_heads
    qkv_bias: bool = False              # Qwen2
    qk_norm: bool = False               # Chameleon
    rope_theta: float = 10000.0
    rope_pct: float = 1.0               # StableLM partial rotary
    norm: str = "rms"                   # rms | ln
    act: str = "swiglu"                 # swiglu | gelu
    tie_embeddings: bool = False
    attn_window: Optional[int] = None   # sliding-window (banded) attention
    # hybrid layout: pattern of block kinds, tiled over n_layers
    block_pattern: Tuple[str, ...] = ("attn",)
    # subconfigs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (whisper)
    is_encdec: bool = False
    n_encoder_layers: int = 0
    decoder_len: int = 448              # whisper text context
    # long-context capability: True when decode state is O(1) or banded
    subquadratic: bool = False
    # numerics
    dtype: str = "bfloat16"
    # notes for DESIGN.md arch table
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def is_moe_layer(self, layer: int) -> bool:
        if self.moe is None:
            return False
        m = self.moe
        return layer >= m.first_moe_layer and \
            (layer - m.first_moe_layer) % m.moe_every == 0

    def param_count(self) -> float:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        hd = self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        enc_layers = self.n_encoder_layers if self.is_encdec else 0
        for layer in range(L + enc_layers):
            kind = self.block_kind(layer % max(L, 1))
            if kind == "attn":
                attn = d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) \
                    + (self.n_heads * hd) * d
                total += attn
                if self.is_encdec and layer < L:   # decoder cross-attn
                    total += attn
            elif kind == "mamba":
                s = self.ssm or SSMConfig()
                di = s.expand * d
                total += d * di * 2 + di * (2 * s.d_state + 2) + di * d \
                    + di * s.d_conv
            elif kind == "rwkv":
                total += 4 * d * d + 6 * d   # r,k,v,o + decay/bonus params
            if self.is_moe_layer(layer % max(L, 1)):
                m = self.moe
                experts = m.n_experts + m.n_shared_experts
                total += experts * 3 * d * m.d_expert_ff
                total += d * m.n_experts  # router
                if m.dense_residual:
                    total += 3 * d * ff
            elif kind in ("attn", "mamba"):
                n_mats = 3 if self.act == "swiglu" else 2
                total += n_mats * d * ff
            elif kind == "rwkv":
                total += 3 * d * ff          # rwkv channel mix (r,k,v)
        return float(total)

    def active_param_count(self) -> float:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        expert_all = sum(
            (m.n_experts - m.top_k) * 3 * self.d_model * m.d_expert_ff
            for layer in range(self.n_layers) if self.is_moe_layer(layer))
        return float(full - expert_all)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kwargs = dataclasses.asdict(self)
        kwargs.update(
            n_layers=min(self.n_layers, 2 * len(self.block_pattern)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=256,
            vocab=512,
            head_dim=32,
        )
        if self.moe is not None:
            kwargs["moe"] = MoEConfig(
                n_experts=4, top_k=min(self.moe.top_k, 2), d_expert_ff=64,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                dense_residual=self.moe.dense_residual,
                first_moe_layer=min(self.moe.first_moe_layer, 1),
                moe_every=self.moe.moe_every,
            )
        else:
            kwargs["moe"] = None
        if self.ssm is not None:
            kwargs["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2)
        else:
            kwargs["ssm"] = None
        kwargs["block_pattern"] = tuple(self.block_pattern)
        if self.is_encdec:
            kwargs["n_encoder_layers"] = 2
            kwargs["decoder_len"] = 32
        return ModelConfig(**kwargs)


# ---------------------------------------------------------------------------
# Input shape sets (assigned): every LM arch runs all four unless skipped
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k only for sub-quadratic archs (assignment rule)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
