"""Whisper large-v3 -- enc-dec audio transformer, conv frontend stubbed [arXiv:2212.04356]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866,
    norm="ln", act="gelu", rope_pct=0.0,       # learned/sinusoidal positions
    is_encdec=True, n_encoder_layers=32, decoder_len=448,
    source="arXiv:2212.04356; frontend stub provides frame embeddings",
)
