"""Geometry x mechanism x reordering x thread sweep harness.

Answers the paper's §V question quantitatively: replay the same SpMV
demand traces (FD and R-MAT, several sizes) through candidate hierarchies
-- baseline, victim cache, miss cache, stream buffers, combined -- and
collect topdown metrics for each, so "does a victim cache + stream
buffers close the FD vs R-MAT gap?" becomes a table instead of an
argument.  The reorder axis (`reorderings=` / `reorder_sweep`) crosses
the same grid with the software permutations from `repro.reorder`.

Threads appear in two forms:

  * `run_sweep(threads_list=...)` keeps the analytic shortcut (paper
    finding F2: serial and parallel miss rates match): one
    representative core replays its row slice against an L3 share
    divided by the socket's cores.
  * `scaling_sweep` (the thread axis proper, 1-32) drives
    `repro.parallel`: every thread replays its `RowPartition` slice,
    private L1/L2 per thread, one genuinely shared, contended LLC per
    socket plus a DRAM bandwidth model -- this is what speedup curves
    and `report.scaling_report` are built from.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_model import SANDY_BRIDGE, MachineModel
from repro.core.formats import CSR
from repro.core.generators import fd_matrix, rmat_matrix

from .events import EventCounters
from .hierarchy import Hierarchy, HierarchySpec, spmv_address_trace
from .topdown import TopdownSummary, topdown_summary

# The paper's §V candidate mechanisms, by report label.  Entry sizes follow
# the related SimpleScalar study (small fully-associative structures).
MECHANISMS: Dict[str, HierarchySpec] = {
    "baseline": HierarchySpec(),
    "victim-cache": HierarchySpec(victim_entries=64),
    "miss-cache": HierarchySpec(miss_entries=64),
    "stream-buffers": HierarchySpec(stream_buffers=8, stream_depth=4),
    "combined": HierarchySpec(victim_entries=64, stream_buffers=8,
                              stream_depth=4),
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (matrix, reorder, mechanism, geometry) cell of a sweep."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int
    threads: int
    mechanism: str
    spec: HierarchySpec
    counters: EventCounters
    summary: TopdownSummary
    reorder: str = "none"     # reordering strategy applied before tracing

    def row(self) -> List:
        return ([self.kind, self.log2n, self.nnz, self.threads,
                 self.reorder, self.mechanism]
                + [getattr(self.summary, f) for f in TopdownSummary.FIELDS]
                + [self.summary.bound()])

    @staticmethod
    def header() -> List[str]:
        return (["kind", "log2n", "nnz", "threads", "reorder", "mechanism"]
                + list(TopdownSummary.FIELDS) + ["bound"])


def _matrix(kind: str, n: int, seed: int = 0) -> CSR:
    return fd_matrix(n, seed=seed) if kind == "fd" \
        else rmat_matrix(n, seed=seed)


# Sweep plans pin a permuted CSR plus a memoized full address trace each
# (several MB per 2^16 cell), so they get their own small cache rather
# than crowding `plan.DEFAULT_CACHE` (whose entries back live spmv
# traffic).  Lazily constructed to keep module import light.
_PLAN_CACHE = None


def sweep_plan_cache():
    global _PLAN_CACHE
    if _PLAN_CACHE is None:
        from repro.plan import PlanCache

        _PLAN_CACHE = PlanCache(max_plans=8)
    return _PLAN_CACHE


def _planned(base: CSR, strategy):
    """One cached plan per (matrix contents, reordering): the sweep's
    compile-once step.  The plan holds the permuted CSR and memoizes its
    address trace, so crossing the mechanism/thread/geometry axes (and
    re-running a sweep in the same process) re-analyzes and re-permutes
    nothing.  `strategy` is a `repro.reorder` callable or None."""
    return sweep_plan_cache().get_or_compile(
        base, reorder=strategy, predictor="none", format="csr",
        use_pallas=False, keep_csr=True)


def _thread_slice(trace_csr: CSR, threads: int) -> Tuple[CSR, int]:
    """Representative core's row slice (contiguous, like rowblock_equal)."""
    if threads <= 1:
        return trace_csr, trace_csr.nnz
    n = trace_csr.n_rows
    rows_per = -(-n // threads)
    indptr = np.asarray(trace_csr.indptr)
    lo_r, hi_r = 0, min(rows_per, n)   # core 0 (rows are permuted: typical)
    lo_p, hi_p = int(indptr[lo_r]), int(indptr[hi_r])
    sub = CSR(
        data=trace_csr.data[lo_p:hi_p],
        indices=trace_csr.indices[lo_p:hi_p],
        indptr=trace_csr.indptr[lo_r:hi_r + 1] - lo_p,
        n_rows=hi_r - lo_r, n_cols=trace_csr.n_cols,
    )
    return sub, sub.nnz


def run_point(csr: CSR, spec: HierarchySpec,
              machine: MachineModel = SANDY_BRIDGE,
              threads: int = 1, sweeps: int = 2,
              trace=None) -> EventCounters:
    """Replay one matrix through one hierarchy; returns warm-sweep counters.

    With threads > 1 the representative core's slice is replayed through a
    hierarchy whose L3 share is capacity / threads-on-socket.  `trace`
    (ndarray or list of line ids) overrides the matrix-derived trace so a
    prebuilt one can be shared across mechanisms.
    """
    if threads > 1:
        tps = min(threads, machine.cores_per_socket)
        spec = dataclasses.replace(
            spec, l3_bytes=(spec.l3_bytes or machine.l3_bytes) // tps)
    if trace is None:
        if threads > 1:
            csr, _ = _thread_slice(csr, threads)
        trace = spmv_address_trace(csr, machine)
    return spec.instantiate(machine).run_trace(trace, sweeps=sweeps)


# ---------------------------------------------------------------------------
# Per-cell execution (the unit `telemetry.runner` shards and checkpoints).
# Every cell function is a pure function of its arguments, so serial thin
# clients and worker processes produce bit-identical points -- the memos
# below are per-process accelerators, never semantic state.
# ---------------------------------------------------------------------------

# (kind, log2n, rlabel, strategy, threads, seed, machine) -> prepared
# replay inputs.  Sorted cell order keeps consecutive cells on the same
# plan, so a tiny cache suffices; entries hold a full trace list (MBs at
# 2^16), hence the small bound.
_TRACE_MEMO: Dict[Tuple, Tuple] = {}
_TRACE_MEMO_MAX = 3


def _cell_inputs(kind: str, log2n: int, rlabel: str, strategy, threads: int,
                 seed: int, machine: MachineModel):
    """(sub_csr, sub_nnz, full_nnz, trace_list) for one mech cell."""
    key = (kind, log2n, rlabel, strategy, threads, seed, machine)
    hit = _TRACE_MEMO.get(key)
    if hit is not None:
        return hit
    base = _matrix(kind, 2 ** log2n, seed=seed)
    p = _planned(base, strategy)
    full = p.csr
    if threads <= 1:
        sub, sub_nnz = full, full.nnz
        trace = p.address_trace(machine).tolist()
    else:
        sub, sub_nnz = _thread_slice(full, threads)
        trace = spmv_address_trace(sub, machine).tolist()
    if len(_TRACE_MEMO) >= _TRACE_MEMO_MAX:
        _TRACE_MEMO.pop(next(iter(_TRACE_MEMO)))
    out = (sub, sub_nnz, int(full.nnz), trace)
    _TRACE_MEMO[key] = out
    return out


def run_mech_cell(kind: str, log2n: int, rlabel: str, strategy,
                  threads: int, mech_label: str, spec: HierarchySpec,
                  machine: MachineModel = SANDY_BRIDGE,
                  sweeps: int = 2, seed: int = 0) -> SweepPoint:
    """One (matrix, reorder, thread, mechanism) cell of `run_sweep`."""
    sub, sub_nnz, full_nnz, trace = _cell_inputs(
        kind, log2n, rlabel, strategy, threads, seed, machine)
    c = run_point(sub, spec, machine, threads=threads, sweeps=sweeps,
                  trace=trace)
    return SweepPoint(
        kind=kind, log2n=log2n, nnz=full_nnz, threads=threads,
        mechanism=mech_label, spec=spec, counters=c, reorder=rlabel,
        summary=topdown_summary(c, machine, sub_nnz))


def run_sweep(log2ns: Sequence[int] = (12, 14, 16),
              kinds: Sequence[str] = ("fd", "rmat"),
              mechanisms: Optional[Dict[str, HierarchySpec]] = None,
              machine: MachineModel = SANDY_BRIDGE,
              threads_list: Sequence[int] = (1,),
              sweeps: int = 2, seed: int = 0,
              reorderings: Optional[Dict] = None,
              workers: int = 1,
              ckpt_dir: Optional[str] = None) -> List[SweepPoint]:
    """The full grid, in sorted canonical cell order.  Each (kind, size,
    reorder) cell is compiled ONCE into a cached `repro.plan` plan
    (permutation applied, trace memoized) and replayed across the
    mechanism/thread axes, so mechanism columns are exactly comparable
    and repeated sweeps in one process re-analyze nothing.

    `reorderings` maps a label to a `repro.reorder` strategy (callable
    CSR -> Reordering) or None for the unpermuted matrix; each strategy is
    applied to the generated matrix *before* slicing and tracing, making
    the sweep a before/after comparison between software reordering and
    the §V hardware mechanisms.

    This is a thin client of `telemetry.runner`: `workers` shards the
    cells across processes and `ckpt_dir` checkpoints completed cells
    (and resumes from them) -- results are bit-identical either way.
    """
    from . import runner

    mechanisms = mechanisms if mechanisms is not None else MECHANISMS
    reorderings = reorderings if reorderings is not None else {"none": None}
    cells = runner.mech_cells(log2ns=log2ns, kinds=kinds,
                              mechanisms=mechanisms,
                              threads_list=threads_list,
                              reorderings=reorderings)
    cfg = runner.SweepConfig(machine=machine, sweeps=sweeps, seed=seed,
                             mechanisms=dict(mechanisms),
                             reorderings=dict(reorderings))
    return runner.execute_cells(cells, cfg, workers=workers,
                                ckpt_dir=ckpt_dir)


def reorder_sweep(log2ns: Sequence[int] = (12,),
                  kinds: Sequence[str] = ("fd", "rmat"),
                  mechanisms: Optional[Dict[str, HierarchySpec]] = None,
                  reorderings: Optional[Dict] = None,
                  machine: MachineModel = SANDY_BRIDGE,
                  threads_list: Sequence[int] = (1,),
                  sweeps: int = 2, seed: int = 0) -> List[SweepPoint]:
    """Before/after sweep: every reordering strategy crossed with the §V
    mechanisms, so `report.reorder_gap_report` can state how much of the
    FD-vs-R-MAT miss-rate gap each permutation closes on its own and
    combined with the hardware fixes."""
    from repro.reorder import STRATEGIES

    if mechanisms is None:
        mechanisms = {"baseline": MECHANISMS["baseline"],
                      "stream-buffers": MECHANISMS["stream-buffers"]}
    if reorderings is None:
        reorderings = dict(STRATEGIES)
        reorderings["none"] = None       # skip the identity permutation work
    return run_sweep(log2ns=log2ns, kinds=kinds, mechanisms=mechanisms,
                     machine=machine, threads_list=threads_list,
                     sweeps=sweeps, seed=seed, reorderings=reorderings)


@dataclasses.dataclass(frozen=True)
class ScalingPoint:
    """One (matrix, reorder, thread-count) cell of a scaling sweep."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int
    threads: int
    reorder: str
    partition: str            # 'equal' | 'balanced'
    imbalance: float          # max/mean nnz over threads (1.0 = perfect)
    speedup: float            # time(1 thread) / time(threads), same cell
    efficiency: float         # speedup / threads
    metrics: object           # repro.parallel.ParallelMetrics

    def row(self) -> List:
        m = self.metrics
        fr = m.stages.fractions()
        return ([self.kind, self.log2n, self.nnz, self.reorder,
                 self.partition, self.threads, self.speedup, self.efficiency,
                 m.time_s * 1e6, self.imbalance, m.l2_mpki_mean,
                 m.l2_mpki_max, float(np.mean(m.llc_mpki)), m.dram_util,
                 m.pf_on_frac, m.stages.bound(), fr["retiring"],
                 fr["frontend"], fr["backend_llc"], fr["backend_dram"],
                 fr["backend_contention"], fr["backend_bandwidth"]])

    @staticmethod
    def header() -> List[str]:
        return ["kind", "log2n", "nnz", "reorder", "partition", "threads",
                "speedup", "efficiency", "time_us", "imbalance",
                "l2_mpki_mean", "l2_mpki_max", "llc_mpki_mean", "dram_util",
                "pf_on", "bound", "retiring", "frontend", "llc_frac",
                "dram_frac", "contention", "bw_frac"]


# 1-thread reference times for speedup columns, memoized per process so
# the thread axis pays for its baseline replay once.  Recomputing it in
# another process yields the identical float (the replay and time model
# are deterministic pure functions), so this never breaks bit-identity.
_T1_MEMO: Dict[Tuple, float] = {}


def _scaling_run(kind: str, log2n: int, rlabel: str, strategy,
                 partition: str, threads: int, spec,
                 machine: MachineModel, sweeps: int, seed: int):
    from repro.core.partition import (nnz_split, rowblock_balanced,
                                      rowblock_equal)
    from repro.parallel import nnz_partitioned_traces, simulate_parallel

    base = _matrix(kind, 2 ** log2n, seed=seed)
    p = _planned(base, strategy)
    csr = p.csr
    trace = p.address_trace(machine)
    if partition == "merge":
        part = nnz_split(csr, threads)
        slices = nnz_partitioned_traces(csr, part, machine, trace=trace)
        _, m = simulate_parallel(csr, part, machine, spec, sweeps=sweeps,
                                 traces=slices)
    else:
        part_fn = (rowblock_balanced if partition == "balanced"
                   else rowblock_equal)
        part = part_fn(csr, threads)
        _, m = simulate_parallel(csr, part, machine, spec, sweeps=sweeps,
                                 trace=trace)
    return csr, part, m


def run_scaling_cell(kind: str, log2n: int, rlabel: str, strategy,
                     partition: str, threads: int, spec=None,
                     machine: MachineModel = SANDY_BRIDGE,
                     sweeps: int = 2, seed: int = 0) -> ScalingPoint:
    """One (matrix, reorder, partition, thread-count) cell of
    `scaling_sweep`, including its own 1-thread speedup reference
    (memoized per process)."""
    from repro.parallel import ParallelSpec

    spec = spec if spec is not None else ParallelSpec()
    csr, part, m = _scaling_run(kind, log2n, rlabel, strategy, partition,
                                threads, spec, machine, sweeps, seed)
    t1_key = (kind, log2n, rlabel, partition, spec, machine, sweeps, seed)
    t1_time = _T1_MEMO.get(t1_key)
    if t1_time is None:
        if part.n_parts == 1:
            t1_time = m.time_s
        else:
            _, _, m1 = _scaling_run(kind, log2n, rlabel, strategy, partition,
                                    1, spec, machine, sweeps, seed)
            t1_time = m1.time_s
        _T1_MEMO[t1_key] = t1_time
    speedup = t1_time / max(m.time_s, 1e-30)
    # partitioners cap parts at n_rows; record what ran
    threads_eff = part.n_parts
    return ScalingPoint(
        kind=kind, log2n=log2n, nnz=csr.nnz, threads=threads_eff,
        reorder=rlabel, partition=partition, imbalance=part.imbalance(),
        speedup=speedup, efficiency=speedup / threads_eff, metrics=m)


def scaling_sweep(log2ns: Sequence[int] = (12,),
                  kinds: Sequence[str] = ("fd", "rmat"),
                  threads_list: Sequence[int] = (1, 2, 4, 8, 16, 32),
                  spec=None, machine: MachineModel = SANDY_BRIDGE,
                  partition: str = "equal",
                  reorderings: Optional[Dict] = None,
                  sweeps: int = 2, seed: int = 0,
                  workers: int = 1,
                  ckpt_dir: Optional[str] = None) -> List[ScalingPoint]:
    """The thread axis: multithreaded replay through `repro.parallel`.

    For every (kind, size, reorder) the matrix is partitioned per thread
    count and replayed through private caches + the shared, contended
    LLC; speedup is measured against the same cell's 1-thread replay
    (computed even when 1 is not in `threads_list`).  `reorderings` has
    `run_sweep` semantics, so "how much of the scaling gap does RCM
    close?" is one sweep: `reorderings={"none": None, "rcm": reorder.rcm}`.

    `partition` is 'equal' (row counts), 'balanced' (row blocks split on
    the nnz CDF) or 'merge' (the segmented/merge-CSR execution: equal
    *nonzero* segments that may cut mid-row, sliced from the same global
    trace by `parallel.nnz_partitioned_traces`).

    Thin client of `telemetry.runner` (sorted canonical cell order;
    `workers`/`ckpt_dir` shard and checkpoint the grid, bit-identically
    to the serial path).
    """
    from repro.parallel import ParallelSpec

    from . import runner

    spec = spec if spec is not None else ParallelSpec()
    reorderings = reorderings if reorderings is not None else {"none": None}
    cells = runner.scaling_cells(log2ns=log2ns, kinds=kinds,
                                 threads_list=threads_list,
                                 partition=partition,
                                 reorderings=reorderings)
    cfg = runner.SweepConfig(machine=machine, sweeps=sweeps, seed=seed,
                             reorderings=dict(reorderings),
                             parallel_spec=spec)
    return runner.execute_cells(cells, cfg, workers=workers,
                                ckpt_dir=ckpt_dir)


@dataclasses.dataclass(frozen=True)
class GraphPoint:
    """One (matrix, analytic) cell of a graph sweep: a whole iterative
    analytic, with per-iteration cache behavior from the plan's memoized
    trace (iteration 1 cold, later iterations warm)."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int                  # of the analytic's operand matrix
    analytic: str             # 'pagerank' | 'bfs' | 'sssp' | ...
    semiring: str
    n_iters: int
    converged: bool
    iters: Tuple              # TopdownSummary per iteration
    format_name: str = "csr"  # the plan's chosen container format

    @property
    def cold_cycles_per_nnz(self) -> float:
        return self.iters[0].cycles_per_nnz if self.iters else 0.0

    @property
    def warm_cycles_per_nnz(self) -> float:
        tail = self.iters[1:] or self.iters
        if not tail:
            return 0.0
        return float(np.mean([s.cycles_per_nnz for s in tail]))

    @property
    def total_cycles_per_nnz(self) -> float:
        """Whole-analytic cost: per-iteration cycles/nnz summed over the
        run -- what the FD/R-MAT gap compounds into."""
        return float(sum(s.cycles_per_nnz for s in self.iters))

    def row(self) -> List:
        # a 0-iteration run (converged before its first SpMV) still renders
        return [self.kind, self.log2n, self.nnz, self.analytic,
                self.semiring, self.format_name, self.n_iters,
                int(self.converged),
                self.cold_cycles_per_nnz, self.warm_cycles_per_nnz,
                self.total_cycles_per_nnz,
                self.iters[0].l2_mpki if self.iters else 0.0,
                self.iters[-1].l2_mpki if self.iters else 0.0,
                self.iters[0].bound() if self.iters else "",
                self.iters[-1].bound() if self.iters else ""]

    @staticmethod
    def header() -> List[str]:
        return ["kind", "log2n", "nnz", "analytic", "semiring", "format",
                "n_iters", "converged", "cold_cyc_nnz", "warm_cyc_nnz",
                "total_cyc_nnz", "l2_mpki_cold", "l2_mpki_warm",
                "bound_cold", "bound_warm"]


def graph_sweep(log2ns: Sequence[int] = (10,),
                kinds: Sequence[str] = ("fd", "rmat"),
                analytics: Sequence[str] = ("pagerank", "bfs", "sssp"),
                spec: Optional[HierarchySpec] = None,
                machine: MachineModel = SANDY_BRIDGE,
                seed: int = 0, max_iters: int = 64,
                format: Optional[str] = None,
                workers: int = 1,
                ckpt_dir: Optional[str] = None) -> List[GraphPoint]:
    """Whole-analytic axis: run each `repro.graph` driver to convergence,
    then replay its plan's memoized address trace once per executed
    iteration through a warm hierarchy.  The per-iteration summaries show
    how the single-SpMV FD-vs-R-MAT gap compounds across a full PageRank /
    BFS / SSSP run (`report.graph_gap_report` tabulates it).

    Source-based analytics (bfs, sssp) start from the max-out-degree
    vertex (a hub -- vertex 0 can be edgeless on sparse R-MAT draws);
    pagerank starts from a seeded random restart vector so near-regular
    FD grids don't begin at their own fixpoint.

    `format=None` (default) lets each plan's structure analysis pick the
    container -- power-law R-MAT auto-routes to the hybrid row split
    (hyb) -- while an explicit name (e.g. "csr") pins every plan to that
    format, giving benches a fixed-format baseline to quantify what the
    nnz-balanced candidates recover.
    """
    from . import runner

    cells = runner.graph_cells(log2ns=log2ns, kinds=kinds,
                               analytics=analytics, format=format)
    cfg = runner.SweepConfig(machine=machine, seed=seed, hier_spec=spec,
                             max_iters=max_iters, graph_format=format)
    return runner.execute_cells(cells, cfg, workers=workers,
                                ckpt_dir=ckpt_dir)


def run_graph_cell(kind: str, log2n: int, analytic: str,
                   spec: Optional[HierarchySpec] = None,
                   machine: MachineModel = SANDY_BRIDGE,
                   seed: int = 0, max_iters: int = 64,
                   format: Optional[str] = None) -> GraphPoint:
    """One (matrix, analytic) cell of `graph_sweep`: run the driver to
    convergence, then replay its plan's trace once per iteration."""
    from repro.graph import DRIVERS
    from repro.graph.telemetry import iteration_summaries

    base = _matrix(kind, 2 ** log2n, seed=seed)
    source = int(np.argmax(np.diff(np.asarray(base.indptr))))
    r0 = np.random.default_rng(seed).uniform(
        0.5, 1.5, size=base.n_rows).astype(np.float32)
    driver = DRIVERS[analytic]
    if analytic in ("bfs", "sssp"):
        res = driver(base, source, max_iters=max_iters, format=format)
    elif analytic == "pagerank":
        res = driver(base, r0=r0, max_iters=max_iters, format=format)
    else:
        res = driver(base, max_iters=max_iters, format=format)
    iters = tuple(iteration_summaries(
        res.plan, res.n_iters, machine=machine, spec=spec))
    return GraphPoint(
        kind=kind, log2n=log2n, nnz=int(res.plan.csr.nnz),
        analytic=analytic, semiring=res.plan.semiring,
        n_iters=int(res.n_iters), converged=bool(res.converged),
        iters=iters, format_name=res.plan.format_name)


def geometry_sweep(log2n: int = 14,
                   kinds: Sequence[str] = ("fd", "rmat"),
                   l2_kb: Sequence[int] = (128, 256, 512),
                   ways: Sequence[Optional[int]] = (8, None),
                   machine: MachineModel = SANDY_BRIDGE,
                   sweeps: int = 2, seed: int = 0) -> List[SweepPoint]:
    """Cache-size x associativity sweep at fixed size (mechanisms off)."""
    specs = {}
    for kb in l2_kb:
        for w in ways:
            wlab = "full" if w is None else f"{w}way"
            specs[f"l2-{kb}k-{wlab}"] = HierarchySpec(
                l2_bytes=kb * 1024, ways=w)
    return run_sweep(log2ns=(log2n,), kinds=kinds, mechanisms=specs,
                     machine=machine, sweeps=sweeps, seed=seed)
