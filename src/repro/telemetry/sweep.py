"""Geometry x mechanism x reordering x thread sweep harness.

Answers the paper's §V question quantitatively: replay the same SpMV
demand traces (FD and R-MAT, several sizes) through candidate hierarchies
-- baseline, victim cache, miss cache, stream buffers, combined -- and
collect topdown metrics for each, so "does a victim cache + stream
buffers close the FD vs R-MAT gap?" becomes a table instead of an
argument.  The reorder axis (`reorderings=` / `reorder_sweep`) crosses
the same grid with the software permutations from `repro.reorder`.

Threads appear in two forms:

  * `run_sweep(threads_list=...)` keeps the analytic shortcut (paper
    finding F2: serial and parallel miss rates match): one
    representative core replays its row slice against an L3 share
    divided by the socket's cores.
  * `scaling_sweep` (the thread axis proper, 1-32) drives
    `repro.parallel`: every thread replays its `RowPartition` slice,
    private L1/L2 per thread, one genuinely shared, contended LLC per
    socket plus a DRAM bandwidth model -- this is what speedup curves
    and `report.scaling_report` are built from.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_model import SANDY_BRIDGE, MachineModel
from repro.core.formats import CSR
from repro.core.generators import fd_matrix, rmat_matrix

from .events import EventCounters
from .hierarchy import Hierarchy, HierarchySpec, spmv_address_trace
from .topdown import TopdownSummary, topdown_summary

# The paper's §V candidate mechanisms, by report label.  Entry sizes follow
# the related SimpleScalar study (small fully-associative structures).
MECHANISMS: Dict[str, HierarchySpec] = {
    "baseline": HierarchySpec(),
    "victim-cache": HierarchySpec(victim_entries=64),
    "miss-cache": HierarchySpec(miss_entries=64),
    "stream-buffers": HierarchySpec(stream_buffers=8, stream_depth=4),
    "combined": HierarchySpec(victim_entries=64, stream_buffers=8,
                              stream_depth=4),
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (matrix, reorder, mechanism, geometry) cell of a sweep."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int
    threads: int
    mechanism: str
    spec: HierarchySpec
    counters: EventCounters
    summary: TopdownSummary
    reorder: str = "none"     # reordering strategy applied before tracing

    def row(self) -> List:
        return ([self.kind, self.log2n, self.nnz, self.threads,
                 self.reorder, self.mechanism]
                + [getattr(self.summary, f) for f in TopdownSummary.FIELDS])

    @staticmethod
    def header() -> List[str]:
        return (["kind", "log2n", "nnz", "threads", "reorder", "mechanism"]
                + list(TopdownSummary.FIELDS))


def _matrix(kind: str, n: int, seed: int = 0) -> CSR:
    return fd_matrix(n, seed=seed) if kind == "fd" \
        else rmat_matrix(n, seed=seed)


# Sweep plans pin a permuted CSR plus a memoized full address trace each
# (several MB per 2^16 cell), so they get their own small cache rather
# than crowding `plan.DEFAULT_CACHE` (whose entries back live spmv
# traffic).  Lazily constructed to keep module import light.
_PLAN_CACHE = None


def sweep_plan_cache():
    global _PLAN_CACHE
    if _PLAN_CACHE is None:
        from repro.plan import PlanCache

        _PLAN_CACHE = PlanCache(max_plans=8)
    return _PLAN_CACHE


def _planned(base: CSR, strategy):
    """One cached plan per (matrix contents, reordering): the sweep's
    compile-once step.  The plan holds the permuted CSR and memoizes its
    address trace, so crossing the mechanism/thread/geometry axes (and
    re-running a sweep in the same process) re-analyzes and re-permutes
    nothing.  `strategy` is a `repro.reorder` callable or None."""
    return sweep_plan_cache().get_or_compile(
        base, reorder=strategy, predictor="none", format="csr",
        use_pallas=False, keep_csr=True)


def _thread_slice(trace_csr: CSR, threads: int) -> Tuple[CSR, int]:
    """Representative core's row slice (contiguous, like rowblock_equal)."""
    if threads <= 1:
        return trace_csr, trace_csr.nnz
    n = trace_csr.n_rows
    rows_per = -(-n // threads)
    indptr = np.asarray(trace_csr.indptr)
    lo_r, hi_r = 0, min(rows_per, n)   # core 0 (rows are permuted: typical)
    lo_p, hi_p = int(indptr[lo_r]), int(indptr[hi_r])
    sub = CSR(
        data=trace_csr.data[lo_p:hi_p],
        indices=trace_csr.indices[lo_p:hi_p],
        indptr=trace_csr.indptr[lo_r:hi_r + 1] - lo_p,
        n_rows=hi_r - lo_r, n_cols=trace_csr.n_cols,
    )
    return sub, sub.nnz


def run_point(csr: CSR, spec: HierarchySpec,
              machine: MachineModel = SANDY_BRIDGE,
              threads: int = 1, sweeps: int = 2,
              trace=None) -> EventCounters:
    """Replay one matrix through one hierarchy; returns warm-sweep counters.

    With threads > 1 the representative core's slice is replayed through a
    hierarchy whose L3 share is capacity / threads-on-socket.  `trace`
    (ndarray or list of line ids) overrides the matrix-derived trace so a
    prebuilt one can be shared across mechanisms.
    """
    if threads > 1:
        tps = min(threads, machine.cores_per_socket)
        spec = dataclasses.replace(
            spec, l3_bytes=(spec.l3_bytes or machine.l3_bytes) // tps)
    if trace is None:
        if threads > 1:
            csr, _ = _thread_slice(csr, threads)
        trace = spmv_address_trace(csr, machine)
    return spec.instantiate(machine).run_trace(trace, sweeps=sweeps)


def run_sweep(log2ns: Sequence[int] = (12, 14, 16),
              kinds: Sequence[str] = ("fd", "rmat"),
              mechanisms: Optional[Dict[str, HierarchySpec]] = None,
              machine: MachineModel = SANDY_BRIDGE,
              threads_list: Sequence[int] = (1,),
              sweeps: int = 2, seed: int = 0,
              reorderings: Optional[Dict] = None) -> List[SweepPoint]:
    """The full grid.  Each (kind, size, reorder) cell is compiled ONCE
    into a cached `repro.plan` plan (permutation applied, trace memoized)
    and replayed across the mechanism/thread axes, so mechanism columns
    are exactly comparable and repeated sweeps in one process re-analyze
    nothing.

    `reorderings` maps a label to a `repro.reorder` strategy (callable
    CSR -> Reordering) or None for the unpermuted matrix; each strategy is
    applied to the generated matrix *before* slicing and tracing, making
    the sweep a before/after comparison between software reordering and
    the §V hardware mechanisms.
    """
    mechanisms = mechanisms if mechanisms is not None else MECHANISMS
    reorderings = reorderings if reorderings is not None else {"none": None}
    points: List[SweepPoint] = []
    for kind in kinds:
        for log2n in log2ns:
            base = _matrix(kind, 2 ** log2n, seed=seed)
            for rlabel, strategy in reorderings.items():
                # compile-once: the plan pins the permuted matrix (and its
                # memoized full trace) across the mechanism x thread grid
                p = _planned(base, strategy)
                full = p.csr
                for threads in threads_list:
                    if threads <= 1:
                        sub, sub_nnz = full, full.nnz
                        trace = p.address_trace(machine).tolist()
                    else:
                        sub, sub_nnz = _thread_slice(full, threads)
                        trace = spmv_address_trace(sub, machine).tolist()
                    for label, spec in mechanisms.items():
                        c = run_point(sub, spec, machine, threads=threads,
                                      sweeps=sweeps, trace=trace)
                        points.append(SweepPoint(
                            kind=kind, log2n=log2n, nnz=full.nnz,
                            threads=threads, mechanism=label, spec=spec,
                            counters=c, reorder=rlabel,
                            summary=topdown_summary(c, machine, sub_nnz)))
    return points


def reorder_sweep(log2ns: Sequence[int] = (12,),
                  kinds: Sequence[str] = ("fd", "rmat"),
                  mechanisms: Optional[Dict[str, HierarchySpec]] = None,
                  reorderings: Optional[Dict] = None,
                  machine: MachineModel = SANDY_BRIDGE,
                  threads_list: Sequence[int] = (1,),
                  sweeps: int = 2, seed: int = 0) -> List[SweepPoint]:
    """Before/after sweep: every reordering strategy crossed with the §V
    mechanisms, so `report.reorder_gap_report` can state how much of the
    FD-vs-R-MAT miss-rate gap each permutation closes on its own and
    combined with the hardware fixes."""
    from repro.reorder import STRATEGIES

    if mechanisms is None:
        mechanisms = {"baseline": MECHANISMS["baseline"],
                      "stream-buffers": MECHANISMS["stream-buffers"]}
    if reorderings is None:
        reorderings = dict(STRATEGIES)
        reorderings["none"] = None       # skip the identity permutation work
    return run_sweep(log2ns=log2ns, kinds=kinds, mechanisms=mechanisms,
                     machine=machine, threads_list=threads_list,
                     sweeps=sweeps, seed=seed, reorderings=reorderings)


@dataclasses.dataclass(frozen=True)
class ScalingPoint:
    """One (matrix, reorder, thread-count) cell of a scaling sweep."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int
    threads: int
    reorder: str
    partition: str            # 'equal' | 'balanced'
    imbalance: float          # max/mean nnz over threads (1.0 = perfect)
    speedup: float            # time(1 thread) / time(threads), same cell
    efficiency: float         # speedup / threads
    metrics: object           # repro.parallel.ParallelMetrics

    def row(self) -> List:
        m = self.metrics
        return [self.kind, self.log2n, self.nnz, self.reorder,
                self.partition, self.threads, self.speedup, self.efficiency,
                m.time_s * 1e6, self.imbalance, m.l2_mpki_mean,
                m.l2_mpki_max, float(np.mean(m.llc_mpki)), m.dram_util,
                m.pf_on_frac]

    @staticmethod
    def header() -> List[str]:
        return ["kind", "log2n", "nnz", "reorder", "partition", "threads",
                "speedup", "efficiency", "time_us", "imbalance",
                "l2_mpki_mean", "l2_mpki_max", "llc_mpki_mean", "dram_util",
                "pf_on"]


def scaling_sweep(log2ns: Sequence[int] = (12,),
                  kinds: Sequence[str] = ("fd", "rmat"),
                  threads_list: Sequence[int] = (1, 2, 4, 8, 16, 32),
                  spec=None, machine: MachineModel = SANDY_BRIDGE,
                  partition: str = "equal",
                  reorderings: Optional[Dict] = None,
                  sweeps: int = 2, seed: int = 0) -> List[ScalingPoint]:
    """The thread axis: multithreaded replay through `repro.parallel`.

    For every (kind, size, reorder) the matrix is partitioned per thread
    count and replayed through private caches + the shared, contended
    LLC; speedup is measured against the same cell's 1-thread replay
    (computed even when 1 is not in `threads_list`).  `reorderings` has
    `run_sweep` semantics, so "how much of the scaling gap does RCM
    close?" is one sweep: `reorderings={"none": None, "rcm": reorder.rcm}`.

    `partition` is 'equal' (row counts), 'balanced' (row blocks split on
    the nnz CDF) or 'merge' (the segmented/merge-CSR execution: equal
    *nonzero* segments that may cut mid-row, sliced from the same global
    trace by `parallel.nnz_partitioned_traces`).
    """
    from repro.core.partition import (nnz_split, rowblock_balanced,
                                      rowblock_equal)
    from repro.parallel import (ParallelSpec, nnz_partitioned_traces,
                                simulate_parallel)

    spec = spec if spec is not None else ParallelSpec()
    part_fn = rowblock_balanced if partition == "balanced" else rowblock_equal
    reorderings = reorderings if reorderings is not None else {"none": None}
    points: List[ScalingPoint] = []
    for kind in kinds:
        for log2n in log2ns:
            base = _matrix(kind, 2 ** log2n, seed=seed)
            for rlabel, strategy in reorderings.items():
                # one plan per (matrix, reorder): every thread count below
                # re-slices the plan's cached global trace instead of
                # re-permuting and re-tracing the matrix
                p = _planned(base, strategy)
                csr = p.csr
                trace = p.address_trace(machine)
                tl = sorted(set(threads_list) | {1})
                t1_time = None
                for threads in tl:
                    if partition == "merge":
                        part = nnz_split(csr, threads)
                        slices = nnz_partitioned_traces(csr, part, machine,
                                                        trace=trace)
                        _, m = simulate_parallel(csr, part, machine, spec,
                                                 sweeps=sweeps, traces=slices)
                    else:
                        part = part_fn(csr, threads)
                        _, m = simulate_parallel(csr, part, machine, spec,
                                                 sweeps=sweeps, trace=trace)
                    if threads == 1:
                        t1_time = m.time_s
                    if threads not in threads_list:
                        continue
                    speedup = t1_time / max(m.time_s, 1e-30)
                    # partitioners cap parts at n_rows; record what ran
                    threads_eff = part.n_parts
                    points.append(ScalingPoint(
                        kind=kind, log2n=log2n, nnz=csr.nnz,
                        threads=threads_eff, reorder=rlabel,
                        partition=partition,
                        imbalance=part.imbalance(), speedup=speedup,
                        efficiency=speedup / threads_eff, metrics=m))
    return points


@dataclasses.dataclass(frozen=True)
class GraphPoint:
    """One (matrix, analytic) cell of a graph sweep: a whole iterative
    analytic, with per-iteration cache behavior from the plan's memoized
    trace (iteration 1 cold, later iterations warm)."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int                  # of the analytic's operand matrix
    analytic: str             # 'pagerank' | 'bfs' | 'sssp' | ...
    semiring: str
    n_iters: int
    converged: bool
    iters: Tuple              # TopdownSummary per iteration
    format_name: str = "csr"  # the plan's chosen container format

    @property
    def cold_cycles_per_nnz(self) -> float:
        return self.iters[0].cycles_per_nnz

    @property
    def warm_cycles_per_nnz(self) -> float:
        tail = self.iters[1:] or self.iters
        return float(np.mean([s.cycles_per_nnz for s in tail]))

    @property
    def total_cycles_per_nnz(self) -> float:
        """Whole-analytic cost: per-iteration cycles/nnz summed over the
        run -- what the FD/R-MAT gap compounds into."""
        return float(sum(s.cycles_per_nnz for s in self.iters))

    def row(self) -> List:
        return [self.kind, self.log2n, self.nnz, self.analytic,
                self.semiring, self.format_name, self.n_iters,
                int(self.converged),
                self.cold_cycles_per_nnz, self.warm_cycles_per_nnz,
                self.total_cycles_per_nnz,
                self.iters[0].l2_mpki, self.iters[-1].l2_mpki]

    @staticmethod
    def header() -> List[str]:
        return ["kind", "log2n", "nnz", "analytic", "semiring", "format",
                "n_iters", "converged", "cold_cyc_nnz", "warm_cyc_nnz",
                "total_cyc_nnz", "l2_mpki_cold", "l2_mpki_warm"]


def graph_sweep(log2ns: Sequence[int] = (10,),
                kinds: Sequence[str] = ("fd", "rmat"),
                analytics: Sequence[str] = ("pagerank", "bfs", "sssp"),
                spec: Optional[HierarchySpec] = None,
                machine: MachineModel = SANDY_BRIDGE,
                seed: int = 0, max_iters: int = 64,
                format: Optional[str] = None) -> List[GraphPoint]:
    """Whole-analytic axis: run each `repro.graph` driver to convergence,
    then replay its plan's memoized address trace once per executed
    iteration through a warm hierarchy.  The per-iteration summaries show
    how the single-SpMV FD-vs-R-MAT gap compounds across a full PageRank /
    BFS / SSSP run (`report.graph_gap_report` tabulates it).

    Source-based analytics (bfs, sssp) start from the max-out-degree
    vertex (a hub -- vertex 0 can be edgeless on sparse R-MAT draws);
    pagerank starts from a seeded random restart vector so near-regular
    FD grids don't begin at their own fixpoint.

    `format=None` (default) lets each plan's structure analysis pick the
    container -- power-law R-MAT auto-routes to the hybrid row split
    (hyb) -- while an explicit name (e.g. "csr") pins every plan to that
    format, giving benches a fixed-format baseline to quantify what the
    nnz-balanced candidates recover.
    """
    from repro.graph import DRIVERS
    from repro.graph.telemetry import iteration_summaries

    points: List[GraphPoint] = []
    for kind in kinds:
        for log2n in log2ns:
            base = _matrix(kind, 2 ** log2n, seed=seed)
            source = int(np.argmax(np.diff(np.asarray(base.indptr))))
            r0 = np.random.default_rng(seed).uniform(
                0.5, 1.5, size=base.n_rows).astype(np.float32)
            for name in analytics:
                driver = DRIVERS[name]
                if name in ("bfs", "sssp"):
                    res = driver(base, source, max_iters=max_iters,
                                 format=format)
                elif name == "pagerank":
                    res = driver(base, r0=r0, max_iters=max_iters,
                                 format=format)
                else:
                    res = driver(base, max_iters=max_iters, format=format)
                iters = tuple(iteration_summaries(
                    res.plan, res.n_iters, machine=machine, spec=spec))
                points.append(GraphPoint(
                    kind=kind, log2n=log2n, nnz=res.plan.csr.nnz,
                    analytic=name, semiring=res.plan.semiring,
                    n_iters=res.n_iters, converged=res.converged,
                    iters=iters, format_name=res.plan.format_name))
    return points


def geometry_sweep(log2n: int = 14,
                   kinds: Sequence[str] = ("fd", "rmat"),
                   l2_kb: Sequence[int] = (128, 256, 512),
                   ways: Sequence[Optional[int]] = (8, None),
                   machine: MachineModel = SANDY_BRIDGE,
                   sweeps: int = 2, seed: int = 0) -> List[SweepPoint]:
    """Cache-size x associativity sweep at fixed size (mechanisms off)."""
    specs = {}
    for kb in l2_kb:
        for w in ways:
            wlab = "full" if w is None else f"{w}way"
            specs[f"l2-{kb}k-{wlab}"] = HierarchySpec(
                l2_bytes=kb * 1024, ways=w)
    return run_sweep(log2ns=(log2n,), kinds=kinds, mechanisms=specs,
                     machine=machine, sweeps=sweeps, seed=seed)
