"""Geometry x mechanism x matrix-structure sweep harness.

Answers the paper's §V question quantitatively: replay the same SpMV
demand traces (FD and R-MAT, several sizes) through candidate hierarchies
-- baseline, victim cache, miss cache, stream buffers, combined -- and
collect topdown metrics for each, so "does a victim cache + stream
buffers close the FD vs R-MAT gap?" becomes a table instead of an
argument.

Threads are modeled the way the analytic model does (paper finding F2:
serial and parallel miss rates match): each core replays its contiguous
row slice through a private L2, while the shared L3 capacity is divided
by the cores on the socket.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_model import SANDY_BRIDGE, MachineModel
from repro.core.formats import CSR
from repro.core.generators import fd_matrix, rmat_matrix

from .events import EventCounters
from .hierarchy import Hierarchy, HierarchySpec, spmv_address_trace
from .topdown import TopdownSummary, topdown_summary

# The paper's §V candidate mechanisms, by report label.  Entry sizes follow
# the related SimpleScalar study (small fully-associative structures).
MECHANISMS: Dict[str, HierarchySpec] = {
    "baseline": HierarchySpec(),
    "victim-cache": HierarchySpec(victim_entries=64),
    "miss-cache": HierarchySpec(miss_entries=64),
    "stream-buffers": HierarchySpec(stream_buffers=8, stream_depth=4),
    "combined": HierarchySpec(victim_entries=64, stream_buffers=8,
                              stream_depth=4),
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (matrix, reorder, mechanism, geometry) cell of a sweep."""

    kind: str                 # 'fd' | 'rmat'
    log2n: int
    nnz: int
    threads: int
    mechanism: str
    spec: HierarchySpec
    counters: EventCounters
    summary: TopdownSummary
    reorder: str = "none"     # reordering strategy applied before tracing

    def row(self) -> List:
        return ([self.kind, self.log2n, self.nnz, self.threads,
                 self.reorder, self.mechanism]
                + [getattr(self.summary, f) for f in TopdownSummary.FIELDS])

    @staticmethod
    def header() -> List[str]:
        return (["kind", "log2n", "nnz", "threads", "reorder", "mechanism"]
                + list(TopdownSummary.FIELDS))


def _matrix(kind: str, n: int, seed: int = 0) -> CSR:
    return fd_matrix(n, seed=seed) if kind == "fd" \
        else rmat_matrix(n, seed=seed)


def _thread_slice(trace_csr: CSR, threads: int) -> Tuple[CSR, int]:
    """Representative core's row slice (contiguous, like rowblock_equal)."""
    if threads <= 1:
        return trace_csr, trace_csr.nnz
    n = trace_csr.n_rows
    rows_per = -(-n // threads)
    indptr = np.asarray(trace_csr.indptr)
    lo_r, hi_r = 0, min(rows_per, n)   # core 0 (rows are permuted: typical)
    lo_p, hi_p = int(indptr[lo_r]), int(indptr[hi_r])
    sub = CSR(
        data=trace_csr.data[lo_p:hi_p],
        indices=trace_csr.indices[lo_p:hi_p],
        indptr=trace_csr.indptr[lo_r:hi_r + 1] - lo_p,
        n_rows=hi_r - lo_r, n_cols=trace_csr.n_cols,
    )
    return sub, sub.nnz


def run_point(csr: CSR, spec: HierarchySpec,
              machine: MachineModel = SANDY_BRIDGE,
              threads: int = 1, sweeps: int = 2,
              trace=None) -> EventCounters:
    """Replay one matrix through one hierarchy; returns warm-sweep counters.

    With threads > 1 the representative core's slice is replayed through a
    hierarchy whose L3 share is capacity / threads-on-socket.  `trace`
    (ndarray or list of line ids) overrides the matrix-derived trace so a
    prebuilt one can be shared across mechanisms.
    """
    if threads > 1:
        tps = min(threads, machine.cores_per_socket)
        spec = dataclasses.replace(
            spec, l3_bytes=(spec.l3_bytes or machine.l3_bytes) // tps)
    if trace is None:
        if threads > 1:
            csr, _ = _thread_slice(csr, threads)
        trace = spmv_address_trace(csr, machine)
    return spec.instantiate(machine).run_trace(trace, sweeps=sweeps)


def run_sweep(log2ns: Sequence[int] = (12, 14, 16),
              kinds: Sequence[str] = ("fd", "rmat"),
              mechanisms: Optional[Dict[str, HierarchySpec]] = None,
              machine: MachineModel = SANDY_BRIDGE,
              threads_list: Sequence[int] = (1,),
              sweeps: int = 2, seed: int = 0,
              reorderings: Optional[Dict] = None) -> List[SweepPoint]:
    """The full grid.  Traces are built once per (kind, size, reorder,
    threads) and shared across mechanisms, so mechanism columns are exactly
    comparable.

    `reorderings` maps a label to a `repro.reorder` strategy (callable
    CSR -> Reordering) or None for the unpermuted matrix; each strategy is
    applied to the generated matrix *before* slicing and tracing, making
    the sweep a before/after comparison between software reordering and
    the §V hardware mechanisms.
    """
    mechanisms = mechanisms if mechanisms is not None else MECHANISMS
    reorderings = reorderings if reorderings is not None else {"none": None}
    points: List[SweepPoint] = []
    for kind in kinds:
        for log2n in log2ns:
            base = _matrix(kind, 2 ** log2n, seed=seed)
            for rlabel, strategy in reorderings.items():
                full = base if strategy is None else strategy(base).apply(base)
                for threads in threads_list:
                    sub, sub_nnz = _thread_slice(full, threads)
                    trace = spmv_address_trace(sub, machine).tolist()
                    for label, spec in mechanisms.items():
                        c = run_point(sub, spec, machine, threads=threads,
                                      sweeps=sweeps, trace=trace)
                        points.append(SweepPoint(
                            kind=kind, log2n=log2n, nnz=full.nnz,
                            threads=threads, mechanism=label, spec=spec,
                            counters=c, reorder=rlabel,
                            summary=topdown_summary(c, machine, sub_nnz)))
    return points


def reorder_sweep(log2ns: Sequence[int] = (12,),
                  kinds: Sequence[str] = ("fd", "rmat"),
                  mechanisms: Optional[Dict[str, HierarchySpec]] = None,
                  reorderings: Optional[Dict] = None,
                  machine: MachineModel = SANDY_BRIDGE,
                  threads_list: Sequence[int] = (1,),
                  sweeps: int = 2, seed: int = 0) -> List[SweepPoint]:
    """Before/after sweep: every reordering strategy crossed with the §V
    mechanisms, so `report.reorder_gap_report` can state how much of the
    FD-vs-R-MAT miss-rate gap each permutation closes on its own and
    combined with the hardware fixes."""
    from repro.reorder import STRATEGIES

    if mechanisms is None:
        mechanisms = {"baseline": MECHANISMS["baseline"],
                      "stream-buffers": MECHANISMS["stream-buffers"]}
    if reorderings is None:
        reorderings = dict(STRATEGIES)
        reorderings["none"] = None       # skip the identity permutation work
    return run_sweep(log2ns=log2ns, kinds=kinds, mechanisms=mechanisms,
                     machine=machine, threads_list=threads_list,
                     sweeps=sweeps, seed=seed, reorderings=reorderings)


def geometry_sweep(log2n: int = 14,
                   kinds: Sequence[str] = ("fd", "rmat"),
                   l2_kb: Sequence[int] = (128, 256, 512),
                   ways: Sequence[Optional[int]] = (8, None),
                   machine: MachineModel = SANDY_BRIDGE,
                   sweeps: int = 2, seed: int = 0) -> List[SweepPoint]:
    """Cache-size x associativity sweep at fixed size (mechanisms off)."""
    specs = {}
    for kb in l2_kb:
        for w in ways:
            wlab = "full" if w is None else f"{w}way"
            specs[f"l2-{kb}k-{wlab}"] = HierarchySpec(
                l2_bytes=kb * 1024, ways=w)
    return run_sweep(log2ns=(log2n,), kinds=kinds, mechanisms=specs,
                     machine=machine, sweeps=sweeps, seed=seed)
