"""Topdown metric tree + staged cycle accounting over hardware events.

Two layers:

  * `TopdownStages` / `stage_cycles` -- the **staged pipeline**: every
    simulated SpMV cycle is attributed to exactly one category
    (Retiring, Frontend, Backend-{L1, L2, LLC, DRAM, contention,
    bandwidth}) with an exactness contract: the stage cycles sum
    **bit-exactly** to the run's total cycles.  The contract holds by
    construction -- `repro.parallel.parallel_metrics` *defines* its
    total as `TopdownStages.total_cycles()` (the canonical left-to-right
    sum over `STAGE_FIELDS`), and every report recomputes stages from
    the same counters through the same function.
  * `topdown_tree` / `topdown_summary` -- the VTune-style metric tree
    the paper reads off (staged bound split, per-level cache
    effectiveness, the MPKI family, prefetcher coverage/accuracy,
    mechanism service rates), flattened into `TopdownSummary` report
    rows.

Latency attribution uses the same machine constants as the analytic
model (`MachineModel.l3_hit_cycles`, `.dram_cycles`, `.mlp`) so the
trace-driven and analytic paths are comparable metric-for-metric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

from . import events as ev
from .events import EventCounters

# CSR SpMV inner-loop issue cost per nonzero, load-port bound (same constant
# as cache_model.analytic_metrics_from_profile)
COMPUTE_CPN = 2.9
# victim/miss-cache/stream-buffer hits are near-side fills, not DRAM trips
MECH_HIT_CYCLES = 3.0

# Canonical stage order.  `TopdownStages.total_cycles()` sums the fields
# in THIS order, left to right -- the single definition both the time
# model and the reports use, which is what makes the exactness contract
# bitwise rather than approximate.
STAGE_FIELDS = ("retiring", "frontend", "backend_l1", "backend_l2",
                "backend_llc", "backend_dram", "backend_contention",
                "backend_bandwidth")


@dataclasses.dataclass(frozen=True)
class TopdownStages:
    """One run's cycles attributed to topdown categories (all in cycles).

    retiring            useful SpMV issue work (nnz x COMPUTE_CPN)
    frontend            instruction-delivery excess when SMT
                        oversubscription shares issue ports
    backend_l1          demand hits in the private first level(s) --
                        latency hidden by the OOO window in this model,
                        so the stage is identically 0; it is kept so the
                        accounting names every level it *considered*
    backend_l2          L2 misses served near-side by the paper's §V
                        structures (victim/miss cache, stream buffers)
                        at MECH_HIT_CYCLES
    backend_llc         L2 misses served by the (shared) LLC
    backend_dram        demand lines fetched from DRAM (latency)
    backend_contention  queueing inflation of miss latency near DRAM
                        bandwidth saturation
    backend_bandwidth   per-socket DRAM bandwidth floor: cycles the
                        socket's memory link needs beyond the critical
                        thread's latency estimate
    """

    retiring: float = 0.0
    frontend: float = 0.0
    backend_l1: float = 0.0
    backend_l2: float = 0.0
    backend_llc: float = 0.0
    backend_dram: float = 0.0
    backend_contention: float = 0.0
    backend_bandwidth: float = 0.0

    def total_cycles(self) -> float:
        """THE canonical total: left-to-right sum over STAGE_FIELDS.

        `repro.parallel.parallel_metrics` defines its cycle total via
        this method, so `sum(stages) == metrics.total_cycles` is exact
        by construction, not within tolerance."""
        total = 0.0
        for f in STAGE_FIELDS:
            total = total + getattr(self, f)
        return total

    def fractions(self) -> Dict[str, float]:
        """Stage shares of the total (all 0.0 for an empty run)."""
        total = self.total_cycles()
        if total <= 0.0:
            return {f: 0.0 for f in STAGE_FIELDS}
        return {f: getattr(self, f) / total for f in STAGE_FIELDS}

    def bound(self) -> str:
        """Name of the dominant stage (ties break in STAGE_FIELDS order)."""
        best, best_v = STAGE_FIELDS[0], getattr(self, STAGE_FIELDS[0])
        for f in STAGE_FIELDS[1:]:
            v = getattr(self, f)
            if v > best_v:
                best, best_v = f, v
        return best

    def memory_frac(self) -> float:
        """Share of cycles stalled on the memory system (everything past
        the frontend/retiring split)."""
        total = self.total_cycles()
        if total <= 0.0:
            return 0.0
        mem = (self.backend_l1 + self.backend_l2 + self.backend_llc
               + self.backend_dram + self.backend_contention
               + self.backend_bandwidth)
        return mem / total

    def as_dict(self) -> Dict[str, float]:
        return {f: getattr(self, f) for f in STAGE_FIELDS}

    @classmethod
    def from_dict(cls, d: Mapping[str, float]) -> "TopdownStages":
        return cls(**{f: float(d.get(f, 0.0)) for f in STAGE_FIELDS})


def stage_cycles(c: EventCounters, machine, nnz: int,
                 smt_factor: float = 1.0,
                 queue_factor: float = 1.0) -> TopdownStages:
    """Attribute one thread's replay to topdown stages.

    `machine` is a `MachineModel`-shaped object.  `smt_factor` >= 1 is
    the issue-port oversubscription multiplier (threads beyond the
    socket's cores share ports; the excess is instruction-delivery
    pressure, i.e. frontend-bound).  `queue_factor` >= 1 inflates the
    miss stalls near DRAM saturation; the inflation lands in
    `backend_contention`.  The bandwidth stage belongs to the machine
    roll-up (`machine_stages`), not to a single thread.
    """
    retiring = nnz * COMPUTE_CPN
    frontend = retiring * (smt_factor - 1.0) if smt_factor > 1.0 else 0.0
    mech_hits = c[ev.VICTIM_HIT] + c[ev.MISS_CACHE_HIT] + c[ev.STREAM_HIT]
    backend_l2 = mech_hits * MECH_HIT_CYCLES / machine.mlp
    backend_llc = c[ev.L3_DEMAND_HIT] * machine.l3_hit_cycles / machine.mlp
    backend_dram = c[ev.L3_DEMAND_MISS] * machine.dram_cycles / machine.mlp
    if queue_factor > 1.0:
        stall = backend_l2 + backend_llc + backend_dram
        contention = stall * queue_factor - stall
    else:
        contention = 0.0
    return TopdownStages(
        retiring=retiring, frontend=frontend,
        backend_l1=0.0, backend_l2=backend_l2,
        backend_llc=backend_llc, backend_dram=backend_dram,
        backend_contention=contention, backend_bandwidth=0.0)


def machine_stages(thread_stages: Sequence[TopdownStages],
                   bw_cycles: float) -> TopdownStages:
    """Roll per-thread stages into the machine-level attribution.

    The machine runs as long as its critical (slowest) thread, plus
    whatever the per-socket DRAM link needs beyond that -- so the
    machine stages are the critical thread's stages with the bandwidth
    floor excess in `backend_bandwidth`.  `total_cycles()` of the
    result is the run's total, exactly.
    """
    if not thread_stages:
        return TopdownStages()
    crit = thread_stages[0]
    crit_total = crit.total_cycles()
    for s in thread_stages[1:]:
        t = s.total_cycles()
        if t > crit_total:
            crit, crit_total = s, t
    excess = bw_cycles - crit_total
    return dataclasses.replace(
        crit, backend_bandwidth=excess if excess > 0.0 else 0.0)


@dataclasses.dataclass(frozen=True)
class MetricNode:
    """One node of the topdown tree."""

    name: str
    value: float
    unit: str                       # 'frac' | 'mpki' | 'rate' | 'cycles' | ...
    description: str = ""
    children: Tuple["MetricNode", ...] = ()

    def flatten(self, prefix: str = "") -> Dict[str, float]:
        key = f"{prefix}{self.name}"
        out = {key: self.value}
        for ch in self.children:
            out.update(ch.flatten(prefix=f"{key}."))
        return out

    def render(self, indent: int = 0) -> str:
        if self.unit == "frac":
            val = f"{100.0 * self.value:6.2f} %"
        elif self.unit == "mpki":
            val = f"{self.value:8.3f} /kinst"
        else:
            val = f"{self.value:10.4g} {self.unit}"
        lines = ["  " * indent + f"{self.name:<24s} {val}"]
        for ch in self.children:
            lines.append(ch.render(indent + 1))
        return "\n".join(lines)


def topdown_tree(c: EventCounters, machine, nnz: int) -> MetricNode:
    """Build the topdown tree for one replayed trace.

    `machine` is a `MachineModel`-shaped object; `nnz` sizes the
    instruction stream (instructions = nnz * machine.instr_per_nnz).
    The tree's first child is the staged split (`stage_cycles`); the
    legacy memory-bound / MPKI / prefetch / mechanism groups follow,
    plus the per-level cache `effectiveness` group.
    """
    kinst = nnz * machine.instr_per_nnz / 1e3
    stages = stage_cycles(c, machine, nnz)
    total = stages.total_cycles()
    den = total if total > 0.0 else 1.0
    fr = stages.fractions()

    staged = MetricNode(
        "stages", 1.0 if total > 0.0 else 0.0, "frac",
        "staged cycle attribution (sums bit-exactly to the total)",
        children=tuple(
            MetricNode(f, fr[f], "frac", "staged share of total cycles")
            for f in STAGE_FIELDS))

    memory_bound = MetricNode(
        "memory_bound",
        (stages.backend_l2 + stages.backend_llc + stages.backend_dram) / den,
        "frac", "cycles stalled on the memory hierarchy",
        children=(
            MetricNode("l3_bound", stages.backend_llc / den, "frac",
                       "L2 misses served by L3"),
            MetricNode("dram_bound", stages.backend_dram / den, "frac",
                       "demand lines fetched from DRAM"),
            MetricNode("mechanism_bound", stages.backend_l2 / den, "frac",
                       "misses served by victim/miss-cache/stream buffers"),
        ))

    mpki = MetricNode(
        "mpki", c.per_kinst(ev.L2_DEMAND_MISS, kinst), "mpki",
        "L2 demand misses per kilo-instruction (paper Eq. 1)",
        children=(
            MetricNode("l3_mpki", c.per_kinst(ev.L3_DEMAND_MISS, kinst),
                       "mpki", "L3 demand misses / kinst (paper Eq. 2)"),
            MetricNode("prefetch_mpki",
                       c.per_kinst(ev.L2_PREFETCH_FILL, kinst),
                       "mpki", "prefetch L2 fills / kinst (paper Eq. 3)"),
        ))

    pf_hit = c[ev.L2_PREFETCH_HIT]
    prefetch = MetricNode(
        "prefetch", pf_hit / max(pf_hit + c[ev.L2_DEMAND_MISS], 1), "frac",
        "coverage: demanded lines the prefetcher brought in early",
        children=(
            MetricNode("accuracy",
                       c.rate(ev.L2_PREFETCH_HIT, ev.L2_PREFETCH_FILL),
                       "frac", "prefetched lines that were ever demanded"),
        ))

    l2_miss = max(c[ev.L2_DEMAND_MISS], 1)
    mech_children = []
    for name, event in (("victim_hit_rate", ev.VICTIM_HIT),
                        ("miss_cache_hit_rate", ev.MISS_CACHE_HIT),
                        ("stream_hit_rate", ev.STREAM_HIT)):
        if c[event]:
            mech_children.append(MetricNode(
                name, c[event] / l2_miss, "frac",
                f"L2 misses served ({event})"))
    mech_served = (c[ev.VICTIM_HIT] + c[ev.MISS_CACHE_HIT]
                   + c[ev.STREAM_HIT])
    mechanisms = MetricNode(
        "mechanisms", mech_served / l2_miss, "frac",
        "L2 misses served by the paper's §V structures",
        children=tuple(mech_children))

    # per-level cache effectiveness: fraction of the demand stream that
    # REACHED each level which the level served (the staged view's "why":
    # a DRAM-bound run is one whose upper levels stopped being effective)
    eff_children = []
    for lname in ("L1", "L2", "L3"):
        hits = c[f"{lname}_DEMAND_HIT"]
        reached = hits + c[f"{lname}_DEMAND_MISS"]
        if reached:
            eff_children.append(MetricNode(
                f"{lname.lower()}_eff", hits / reached, "frac",
                f"demand accesses reaching {lname} that {lname} served"))
    effectiveness = MetricNode(
        "effectiveness",
        eff_children[0].value if eff_children else 0.0, "frac",
        "per-level hit rate over the traffic each level actually saw",
        children=tuple(eff_children))

    return MetricNode(
        "spmv", total / max(nnz, 1), "cycles/nnz",
        "estimated cycles per nonzero (1 core)",
        children=(staged, memory_bound, mpki, prefetch, mechanisms,
                  effectiveness))


@dataclasses.dataclass(frozen=True)
class TopdownSummary:
    """Flat headline numbers for reports (one row per sweep point)."""

    l2_mpki: float
    l3_mpki: float
    prefetch_mpki: float
    pf_coverage: float
    pf_accuracy: float
    memory_bound: float
    dram_bound: float
    mech_served_frac: float
    victim_hit_rate: float
    miss_cache_hit_rate: float
    stream_hit_rate: float
    cycles_per_nnz: float
    gflops_est: float
    # staged attribution (fractions of total cycles) + level effectiveness
    retiring_frac: float = 0.0
    mech_bound: float = 0.0       # backend_l2 share (mechanism service cycles)
    llc_bound: float = 0.0        # backend_llc share
    l2_eff: float = 0.0           # L2 demand hit rate (traffic L2 saw)
    llc_eff: float = 0.0          # L3 demand hit rate (traffic L3 saw)

    FIELDS = ("l2_mpki", "l3_mpki", "prefetch_mpki", "pf_coverage",
              "pf_accuracy", "memory_bound", "dram_bound",
              "mech_served_frac", "victim_hit_rate", "miss_cache_hit_rate",
              "stream_hit_rate", "cycles_per_nnz", "gflops_est",
              "retiring_frac", "mech_bound", "llc_bound", "l2_eff",
              "llc_eff")

    def as_dict(self) -> Dict[str, float]:
        return {f: getattr(self, f) for f in self.FIELDS}

    def bound(self) -> str:
        """Dominant single-stream bound category (bandwidth/contention are
        machine-level stages; see `TopdownStages.bound` for those)."""
        cats = (("retiring", self.retiring_frac),
                ("backend_l2", self.mech_bound),
                ("backend_llc", self.llc_bound),
                ("backend_dram", self.dram_bound))
        best, best_v = cats[0]
        for name, v in cats[1:]:
            if v > best_v:
                best, best_v = name, v
        return best


def topdown_summary(c: EventCounters, machine, nnz: int) -> TopdownSummary:
    """Flatten `topdown_tree` into the report row -- the tree is the single
    source of the formulas; this only renames nodes."""
    flat = topdown_tree(c, machine, nnz).flatten()
    cycles_per_nnz = flat["spmv"]
    return TopdownSummary(
        l2_mpki=flat["spmv.mpki"],
        l3_mpki=flat["spmv.mpki.l3_mpki"],
        prefetch_mpki=flat["spmv.mpki.prefetch_mpki"],
        pf_coverage=flat["spmv.prefetch"],
        pf_accuracy=flat["spmv.prefetch.accuracy"],
        memory_bound=flat["spmv.memory_bound"],
        dram_bound=flat["spmv.memory_bound.dram_bound"],
        mech_served_frac=flat["spmv.mechanisms"],
        victim_hit_rate=flat.get("spmv.mechanisms.victim_hit_rate", 0.0),
        miss_cache_hit_rate=flat.get(
            "spmv.mechanisms.miss_cache_hit_rate", 0.0),
        stream_hit_rate=flat.get("spmv.mechanisms.stream_hit_rate", 0.0),
        cycles_per_nnz=cycles_per_nnz,
        gflops_est=(2.0 * machine.freq_ghz / cycles_per_nnz
                    if cycles_per_nnz > 0.0 else 0.0),
        retiring_frac=flat["spmv.stages.retiring"],
        mech_bound=flat["spmv.stages.backend_l2"],
        llc_bound=flat["spmv.stages.backend_llc"],
        l2_eff=flat.get("spmv.effectiveness.l2_eff", 0.0),
        llc_eff=flat.get("spmv.effectiveness.l3_eff", 0.0),
    )
