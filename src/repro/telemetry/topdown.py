"""Topdown metric tree over hardware-event counters.

Rolls the raw counters from `telemetry.hierarchy` into the staged metric
tree the paper reads off VTune (and Arm's topdown_tool formalizes): first
split cycles into retiring vs. memory-bound, then attribute memory-bound
cycles to the level that served the miss, then annotate with the MPKI
family and prefetch/mechanism effectiveness.

Latency attribution uses the same machine constants as the analytic model
(`MachineModel.l3_hit_cycles`, `.dram_cycles`, `.mlp`) so the trace-driven
and analytic paths are comparable metric-for-metric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from . import events as ev
from .events import EventCounters

# CSR SpMV inner-loop issue cost per nonzero, load-port bound (same constant
# as cache_model.analytic_metrics_from_profile)
COMPUTE_CPN = 2.9
# victim/miss-cache/stream-buffer hits are near-side fills, not DRAM trips
MECH_HIT_CYCLES = 3.0


@dataclasses.dataclass(frozen=True)
class MetricNode:
    """One node of the topdown tree."""

    name: str
    value: float
    unit: str                       # 'frac' | 'mpki' | 'rate' | 'cycles' | ...
    description: str = ""
    children: Tuple["MetricNode", ...] = ()

    def flatten(self, prefix: str = "") -> Dict[str, float]:
        key = f"{prefix}{self.name}"
        out = {key: self.value}
        for ch in self.children:
            out.update(ch.flatten(prefix=f"{key}."))
        return out

    def render(self, indent: int = 0) -> str:
        if self.unit == "frac":
            val = f"{100.0 * self.value:6.2f} %"
        elif self.unit == "mpki":
            val = f"{self.value:8.3f} /kinst"
        else:
            val = f"{self.value:10.4g} {self.unit}"
        lines = ["  " * indent + f"{self.name:<24s} {val}"]
        for ch in self.children:
            lines.append(ch.render(indent + 1))
        return "\n".join(lines)


def _cycles(c: EventCounters, machine, nnz: int):
    """(compute, l3_stall, dram_stall, mech_stall) cycle estimates."""
    mech_hits = c[ev.VICTIM_HIT] + c[ev.MISS_CACHE_HIT] + c[ev.STREAM_HIT]
    l3_stall = c[ev.L3_DEMAND_HIT] * machine.l3_hit_cycles / machine.mlp
    dram_stall = c[ev.L3_DEMAND_MISS] * machine.dram_cycles / machine.mlp
    mech_stall = mech_hits * MECH_HIT_CYCLES / machine.mlp
    return nnz * COMPUTE_CPN, l3_stall, dram_stall, mech_stall


def topdown_tree(c: EventCounters, machine, nnz: int) -> MetricNode:
    """Build the topdown tree for one replayed trace.

    `machine` is a `MachineModel`-shaped object; `nnz` sizes the instruction
    stream (instructions = nnz * machine.instr_per_nnz).
    """
    kinst = nnz * machine.instr_per_nnz / 1e3
    compute, l3_st, dram_st, mech_st = _cycles(c, machine, nnz)
    total = compute + l3_st + dram_st + mech_st

    memory_bound = MetricNode(
        "memory_bound", (l3_st + dram_st + mech_st) / total, "frac",
        "cycles stalled on the memory hierarchy",
        children=(
            MetricNode("l3_bound", l3_st / total, "frac",
                       "L2 misses served by L3"),
            MetricNode("dram_bound", dram_st / total, "frac",
                       "demand lines fetched from DRAM"),
            MetricNode("mechanism_bound", mech_st / total, "frac",
                       "misses served by victim/miss-cache/stream buffers"),
        ))

    mpki = MetricNode(
        "mpki", c.per_kinst(ev.L2_DEMAND_MISS, kinst), "mpki",
        "L2 demand misses per kilo-instruction (paper Eq. 1)",
        children=(
            MetricNode("l3_mpki", c.per_kinst(ev.L3_DEMAND_MISS, kinst),
                       "mpki", "L3 demand misses / kinst (paper Eq. 2)"),
            MetricNode("prefetch_mpki",
                       c.per_kinst(ev.L2_PREFETCH_FILL, kinst),
                       "mpki", "prefetch L2 fills / kinst (paper Eq. 3)"),
        ))

    pf_hit = c[ev.L2_PREFETCH_HIT]
    prefetch = MetricNode(
        "prefetch", pf_hit / max(pf_hit + c[ev.L2_DEMAND_MISS], 1), "frac",
        "coverage: demanded lines the prefetcher brought in early",
        children=(
            MetricNode("accuracy",
                       c.rate(ev.L2_PREFETCH_HIT, ev.L2_PREFETCH_FILL),
                       "frac", "prefetched lines that were ever demanded"),
        ))

    l2_miss = max(c[ev.L2_DEMAND_MISS], 1)
    mech_children = []
    for name, event in (("victim_hit_rate", ev.VICTIM_HIT),
                        ("miss_cache_hit_rate", ev.MISS_CACHE_HIT),
                        ("stream_hit_rate", ev.STREAM_HIT)):
        if c[event]:
            mech_children.append(MetricNode(
                name, c[event] / l2_miss, "frac",
                f"L2 misses served ({event})"))
    mech_served = (c[ev.VICTIM_HIT] + c[ev.MISS_CACHE_HIT]
                   + c[ev.STREAM_HIT])
    mechanisms = MetricNode(
        "mechanisms", mech_served / l2_miss, "frac",
        "L2 misses served by the paper's §V structures",
        children=tuple(mech_children))

    return MetricNode(
        "spmv", total / max(nnz, 1), "cycles/nnz",
        "estimated cycles per nonzero (1 core)",
        children=(memory_bound, mpki, prefetch, mechanisms))


@dataclasses.dataclass(frozen=True)
class TopdownSummary:
    """Flat headline numbers for reports (one row per sweep point)."""

    l2_mpki: float
    l3_mpki: float
    prefetch_mpki: float
    pf_coverage: float
    pf_accuracy: float
    memory_bound: float
    dram_bound: float
    mech_served_frac: float
    victim_hit_rate: float
    miss_cache_hit_rate: float
    stream_hit_rate: float
    cycles_per_nnz: float
    gflops_est: float

    FIELDS = ("l2_mpki", "l3_mpki", "prefetch_mpki", "pf_coverage",
              "pf_accuracy", "memory_bound", "dram_bound",
              "mech_served_frac", "victim_hit_rate", "miss_cache_hit_rate",
              "stream_hit_rate", "cycles_per_nnz", "gflops_est")

    def as_dict(self) -> Dict[str, float]:
        return {f: getattr(self, f) for f in self.FIELDS}


def topdown_summary(c: EventCounters, machine, nnz: int) -> TopdownSummary:
    """Flatten `topdown_tree` into the report row -- the tree is the single
    source of the formulas; this only renames nodes."""
    flat = topdown_tree(c, machine, nnz).flatten()
    cycles_per_nnz = flat["spmv"]
    return TopdownSummary(
        l2_mpki=flat["spmv.mpki"],
        l3_mpki=flat["spmv.mpki.l3_mpki"],
        prefetch_mpki=flat["spmv.mpki.prefetch_mpki"],
        pf_coverage=flat["spmv.prefetch"],
        pf_accuracy=flat["spmv.prefetch.accuracy"],
        memory_bound=flat["spmv.memory_bound"],
        dram_bound=flat["spmv.memory_bound.dram_bound"],
        mech_served_frac=flat["spmv.mechanisms"],
        victim_hit_rate=flat.get("spmv.mechanisms.victim_hit_rate", 0.0),
        miss_cache_hit_rate=flat.get(
            "spmv.mechanisms.miss_cache_hit_rate", 0.0),
        stream_hit_rate=flat.get("spmv.mechanisms.stream_hit_rate", 0.0),
        cycles_per_nnz=cycles_per_nnz,
        gflops_est=2.0 * machine.freq_ghz / cycles_per_nnz,
    )
