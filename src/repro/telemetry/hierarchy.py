"""Composable trace-driven memory-hierarchy simulator.

Generalizes the monolithic simulator that used to live in
`repro.core.cache_model` into pluggable pieces:

  * `SetAssocCache`       -- set-associative LRU (ways=None: fully assoc.,
                             the legacy configuration)
  * `SequentialPrefetcher`-- the next-line multi-stream HW prefetcher the
                             paper's Sandy Bridge model assumes (§II-B)
  * miss-path mechanisms  -- the paper's §V candidate architecture fixes,
                             following Jouppi's classic designs:
                             `VictimCache`, `MissCache`, `StreamBuffers`
  * `CacheLevel`          -- one cache + its attached mechanisms
  * `Hierarchy`           -- the level stack; replays address traces and
                             fills an `events.EventCounters`

The simulator is functional, not cycle-accurate: it answers "which
structure served this access" (the quantity VTune's miss counters measure)
and leaves latency attribution to `telemetry.topdown`.

`Hierarchy.default(machine)` reproduces the legacy `cache_model`
configuration bit-for-bit: fully-associative LRU L2/L3 with a 16-stream
next-line prefetcher filling both levels.  `repro.core.cache_model.
simulate_exact` delegates here.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import List, Optional, Sequence

import numpy as np

from .events import (ACCESS, L2_PREFETCH_FILL, L2_PREFETCH_HIT,
                     MISS_CACHE_HIT, MISS_CACHE_PROBE, STREAM_ALLOC,
                     STREAM_FILL, STREAM_HIT, STREAM_PROBE, VICTIM_HIT,
                     VICTIM_PROBE, EventCounters, register_event)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

class SetAssocCache:
    """Set-associative LRU cache over line ids.

    ways=None (or >= capacity) degenerates to one fully-associative set --
    the legacy `cache_model._LRU` behavior.  Each resident line carries a
    "prefetched, not yet demanded" flag so prefetch usefulness is countable.
    """

    __slots__ = ("n_sets", "ways", "sets", "capacity_lines")

    def __init__(self, capacity_lines: int, ways: Optional[int] = None):
        capacity_lines = max(int(capacity_lines), 1)
        if ways is None or ways <= 0 or ways >= capacity_lines:
            self.n_sets, self.ways = 1, capacity_lines
        else:
            self.n_sets = max(capacity_lines // ways, 1)
            self.ways = ways
        self.capacity_lines = self.n_sets * self.ways
        self.sets = [OrderedDict() for _ in range(self.n_sets)]

    def lookup(self, line: int):
        """Demand access: returns (hit, first_hit_on_prefetched_line)."""
        s = self.sets[line % self.n_sets]
        if line in s:
            was_pf = s[line]
            if was_pf:
                s[line] = False
            s.move_to_end(line)
            return True, was_pf
        return False, False

    def contains(self, line: int) -> bool:
        return line in self.sets[line % self.n_sets]

    def insert(self, line: int, prefetched: bool = False) -> Optional[int]:
        """Fill `line`; returns the evicted line id, if any."""
        s = self.sets[line % self.n_sets]
        if line in s:
            s.move_to_end(line)
            return None
        s[line] = prefetched
        if len(s) > self.ways:
            victim, _ = s.popitem(last=False)
            return victim
        return None

    def resident_lines(self) -> int:
        return sum(len(s) for s in self.sets)


class SequentialPrefetcher:
    """Next-line prefetcher: tracks up to `n_streams` ascending line streams;
    on a stream hit it prefetches the next `depth` lines (legacy
    `cache_model._StreamPrefetcher`, moved here verbatim)."""

    __slots__ = ("streams", "n_streams", "depth")

    def __init__(self, n_streams: int = 16, depth: int = 2):
        self.streams: OrderedDict = OrderedDict()  # last line -> None
        self.n_streams = n_streams
        self.depth = depth

    def observe(self, line: int):
        """Returns the list of lines to prefetch."""
        hits = None
        if line - 1 in self.streams or line in self.streams:
            self.streams.pop(line - 1, None)
            self.streams.pop(line, None)
            hits = [line + k for k in range(1, self.depth + 1)]
        self.streams[line] = None
        if len(self.streams) > self.n_streams:
            self.streams.popitem(last=False)
        return hits or []


# ---------------------------------------------------------------------------
# Miss-path mechanisms (paper §V candidates, Jouppi 1990 designs)
# ---------------------------------------------------------------------------

class VictimCache:
    """Small fully-associative buffer of lines recently evicted from the
    attached level.  On a miss it is probed first; a hit swaps the line
    back (the subsequent demand fill into the level models the swap)."""

    name = "victim"

    def __init__(self, n_entries: int = 16):
        self.cap = max(int(n_entries), 1)
        self.entries: OrderedDict = OrderedDict()

    def probe(self, line: int, counters: EventCounters) -> bool:
        counters.inc(VICTIM_PROBE)
        if line in self.entries:
            del self.entries[line]
            counters.inc(VICTIM_HIT)
            return True
        return False

    def on_evict(self, line: int) -> None:
        self.entries[line] = True
        self.entries.move_to_end(line)
        if len(self.entries) > self.cap:
            self.entries.popitem(last=False)


class MissCache:
    """Small fully-associative buffer filled with recently *missed* lines.
    Catches short-term conflict re-misses without storing evictions."""

    name = "miss"

    def __init__(self, n_entries: int = 16):
        self.cap = max(int(n_entries), 1)
        self.entries: OrderedDict = OrderedDict()

    def probe(self, line: int, counters: EventCounters) -> bool:
        counters.inc(MISS_CACHE_PROBE)
        if line in self.entries:
            self.entries.move_to_end(line)
            counters.inc(MISS_CACHE_HIT)
            return True
        self.entries[line] = True
        if len(self.entries) > self.cap:
            self.entries.popitem(last=False)
        return False

    def on_evict(self, line: int) -> None:
        pass


class StreamBuffers:
    """N FIFO stream buffers on the miss path.  A miss that matches a
    buffer head is served from the buffer (which then fetches one more
    line); a miss that matches nothing reallocates the LRU buffer to a new
    sequential stream of `depth` lines."""

    name = "stream"

    def __init__(self, n_streams: int = 4, depth: int = 4):
        self.n_streams = max(int(n_streams), 1)
        self.depth = max(int(depth), 1)
        self.buffers: OrderedDict = OrderedDict()  # id -> deque of lines
        self._next_id = 0

    def probe(self, line: int, counters: EventCounters) -> bool:
        counters.inc(STREAM_PROBE)
        for bid, dq in self.buffers.items():
            if dq and dq[0] == line:
                dq.popleft()
                dq.append(line + self.depth)   # keep the run primed
                counters.inc(STREAM_FILL)
                self.buffers.move_to_end(bid)
                counters.inc(STREAM_HIT)
                return True
        # no buffer tracks this stream: (re)allocate the LRU buffer
        if len(self.buffers) >= self.n_streams:
            self.buffers.popitem(last=False)
        self.buffers[self._next_id] = deque(
            line + k for k in range(1, self.depth + 1))
        self._next_id += 1
        counters.inc(STREAM_ALLOC)
        counters.inc(STREAM_FILL, self.depth)
        return False

    def on_evict(self, line: int) -> None:
        pass


# ---------------------------------------------------------------------------
# Levels and the hierarchy
# ---------------------------------------------------------------------------

class CacheLevel:
    """One cache level plus the mechanisms attached to its miss path."""

    __slots__ = ("name", "cache", "mechanisms", "hit_event", "miss_event")

    def __init__(self, name: str, capacity_lines: int,
                 ways: Optional[int] = None,
                 mechanisms: Sequence = ()):
        self.name = name
        self.cache = SetAssocCache(capacity_lines, ways)
        self.mechanisms = list(mechanisms)
        self.hit_event = register_event(
            f"{name}_DEMAND_HIT", f"demand accesses that hit in {name}")
        self.miss_event = register_event(
            f"{name}_DEMAND_MISS", f"demand accesses that missed {name}")


class Hierarchy:
    """A stack of cache levels with an optional hardware prefetcher.

    The prefetcher observes every demand access *before* the cache lookup
    (hardware cannot tell operands apart -- the paper's mechanism for why
    R-MAT gathers pollute the stream table) and fills every level, matching
    the legacy simulator.
    """

    def __init__(self, levels: Sequence[CacheLevel],
                 prefetcher: Optional[SequentialPrefetcher] = None,
                 pf_level: int = 0):
        """`pf_level` is the index of the level the prefetcher fills into
        and filters against (the L2 in Sandy Bridge terms) -- 0 for the
        legacy two-level stack, 1 when a private L1 sits in front."""
        self.levels = list(levels)
        self.prefetcher = prefetcher
        self.pf_level = pf_level

    # -- construction -------------------------------------------------------

    @classmethod
    def default(cls, machine) -> "Hierarchy":
        """The legacy `cache_model` configuration: fully-associative LRU
        L2/L3 + a `machine.prefetch_streams`-stream next-line prefetcher."""
        return cls.build(machine)

    @classmethod
    def build(cls, machine, ways: Optional[int] = None,
              l2_bytes: Optional[int] = None, l3_bytes: Optional[int] = None,
              l3_ways: Optional[int] = None, prefetcher: bool = True,
              l2_mechanisms: Sequence = ()) -> "Hierarchy":
        """Hierarchy from a `MachineModel`-shaped object (duck-typed: needs
        line_bytes / l2_bytes / l3_bytes / prefetch_streams).

        `ways` sets the L2 associativity only; `l3_ways` the L3's (each
        None -> fully associative), so associativity sweeps on one level
        don't contaminate the other."""
        lb = machine.line_bytes
        levels = [
            CacheLevel("L2", (l2_bytes or machine.l2_bytes) // lb, ways,
                       mechanisms=l2_mechanisms),
            CacheLevel("L3", (l3_bytes or machine.l3_bytes) // lb, l3_ways),
        ]
        pf = (SequentialPrefetcher(machine.prefetch_streams)
              if prefetcher else None)
        return cls(levels, pf)

    # -- replay -------------------------------------------------------------

    def access(self, line: int, counters: EventCounters,
               prefetchable: bool = True) -> str:
        """One demand access; returns the name of what served it."""
        counts = counters.counts
        counts[ACCESS] = counts.get(ACCESS, 0) + 1
        levels = self.levels
        pf = self.prefetcher
        if pf is not None and prefetchable:
            l2cache = levels[self.pf_level].cache
            for pline in pf.observe(line):
                if not l2cache.contains(pline):
                    counts[L2_PREFETCH_FILL] = \
                        counts.get(L2_PREFETCH_FILL, 0) + 1
                    # fill bottom-up (L3 then L2), like the legacy simulator
                    for li in range(len(levels) - 1, self.pf_level - 1, -1):
                        lv = levels[li]
                        ev = lv.cache.insert(
                            pline, prefetched=(li == self.pf_level))
                        if ev is not None:
                            for m in lv.mechanisms:
                                m.on_evict(ev)
        for li, lv in enumerate(levels):
            hit, was_pf = lv.cache.lookup(line)
            if hit:
                counts[lv.hit_event] = counts.get(lv.hit_event, 0) + 1
                if was_pf and li == self.pf_level:
                    counts[L2_PREFETCH_HIT] = \
                        counts.get(L2_PREFETCH_HIT, 0) + 1
                return lv.name
            counts[lv.miss_event] = counts.get(lv.miss_event, 0) + 1
            served = None
            for m in lv.mechanisms:
                if m.probe(line, counters):
                    served = m.name
                    break
            # demand fill on miss (legacy _LRU.access semantics)
            ev = lv.cache.insert(line)
            if ev is not None:
                for m in lv.mechanisms:
                    m.on_evict(ev)
            if served is not None:
                return served
        return "DRAM"

    def replay(self, trace, counters: Optional[EventCounters] = None
               ) -> EventCounters:
        """Replay an iterable of line ids; returns the filled counters."""
        c = counters if counters is not None else EventCounters()
        if isinstance(trace, np.ndarray):
            trace = trace.tolist()
        access = self.access
        for line in trace:
            access(line, c)
        return c

    def run_trace(self, trace, sweeps: int = 2) -> EventCounters:
        """Replay `trace` `sweeps` times against warm state; counters of
        the final (warm) sweep are returned."""
        if isinstance(trace, np.ndarray):
            trace = trace.tolist()
        c = EventCounters()
        for _ in range(max(sweeps, 1)):
            c = EventCounters()
            self.replay(trace, c)
        return c

    def run_spmv(self, csr, machine, sweeps: int = 2) -> EventCounters:
        """Replay the CSR SpMV demand stream `sweeps` times; counters of
        the final (warm) sweep are returned."""
        return self.run_trace(spmv_address_trace(csr, machine).tolist(),
                              sweeps=sweeps)


# ---------------------------------------------------------------------------
# The SpMV address trace (paper Fig. 2's access stream, all five operands)
# ---------------------------------------------------------------------------

def spmv_address_trace(csr, machine) -> np.ndarray:
    """The exact line-id sequence one core issues running CSR SpMV.

    Per row r: rowptr, y, then per nonzero p: value, col-index, x[col[p]].
    Regions are laid out disjointly (16-line guard gaps), identical to the
    legacy `cache_model.simulate_exact` layout, so counter parity holds.
    """
    lb = machine.line_bytes
    ebytes, ibytes = machine.elem_bytes, machine.idx_bytes
    indptr = np.asarray(csr.indptr, dtype=np.int64)
    cols = np.asarray(csr.indices, dtype=np.int64)
    n = csr.n_rows
    nnz = int(cols.shape[0])

    x_base = 0
    x_lines = -(-n * ebytes // lb)
    val_base = x_base + x_lines + 16
    val_lines = -(-nnz * ebytes // lb)
    idx_base = val_base + val_lines + 16
    idx_lines = -(-nnz * ibytes // lb)
    ptr_base = idx_base + idx_lines + 16
    y_base = ptr_base + (-(-(n + 1) * ibytes // lb)) + 16

    rows = np.arange(n, dtype=np.int64)
    rows_rep = np.repeat(rows, np.diff(indptr))
    p = np.arange(nnz, dtype=np.int64)

    trace = np.empty(2 * n + 3 * nnz, dtype=np.int64)
    head = 2 * rows + 3 * indptr[:-1]            # row-header positions
    trace[head] = ptr_base + (rows * ibytes) // lb
    trace[head + 1] = y_base + (rows * ebytes) // lb
    body = 2 * (rows_rep + 1) + 3 * p            # nonzero positions
    trace[body] = val_base + (p * ebytes) // lb
    trace[body + 1] = idx_base + (p * ibytes) // lb
    trace[body + 2] = x_base + (cols * ebytes) // lb
    return trace


def hyb_address_trace(hyb, machine, light_counts=None) -> np.ndarray:
    """The demand stream of the hybrid row-split execution: the light ELL
    launch (row-major over the (n_rows, light_width) slab) followed by
    the heavy launch over the column-sorted COO stream.  Because the
    heavy stream is column-sorted, its x gathers ascend -- hub-row
    gathers turn from a random walk into one streaming pass, which is
    the locality the hybrid split buys.  Regions are disjoint with the
    same 16-line guard layout as `spmv_address_trace`.

    `light_counts` (per-row count of *real* light entries, 0 for heavy
    rows) restricts the light stream to demand accesses at slab
    addresses -- the accounting `spmv_address_trace` uses for every
    other format, where padding is lane fill the vector unit streams
    for free, not a gathered demand miss.  Without it the full slab is
    streamed, padding slots priced like real ones (the conservative raw
    kernel stream).  `format_address_trace` always passes the counts,
    so planned traces are comparable across formats."""
    lb = machine.line_bytes
    ebytes, ibytes = machine.elem_bytes, machine.idx_bytes
    n, w = hyb.n_rows, hyb.light_width
    hnnz = int(hyb.hvals.shape[0])
    lidx = np.asarray(hyb.indices, dtype=np.int64).reshape(-1)
    hcols = np.asarray(hyb.hcols, dtype=np.int64)
    hrows = np.asarray(hyb.hrows, dtype=np.int64)

    x_base = 0
    x_lines = -(-hyb.n_cols * ebytes // lb)
    lval_base = x_base + x_lines + 16
    lval_lines = -(-n * w * ebytes // lb)
    lidx_base = lval_base + lval_lines + 16
    lidx_lines = -(-n * w * ibytes // lb)
    y_base = lidx_base + lidx_lines + 16
    y_lines = -(-n * ebytes // lb)
    hval_base = y_base + y_lines + 16
    hval_lines = -(-hnnz * ebytes // lb)
    hrow_base = hval_base + hval_lines + 16
    hrow_lines = -(-hnnz * ibytes // lb)
    hcol_base = hrow_base + hrow_lines + 16

    # light launch: per row: y, then per real slot: value, index, x[index]
    rows = np.arange(n, dtype=np.int64)
    if light_counts is None:
        counts = np.full(n, w, dtype=np.int64)
    else:
        counts = np.minimum(np.asarray(light_counts, dtype=np.int64), w)
    total = int(counts.sum())
    cum0 = np.concatenate([[0], np.cumsum(counts)[:-1]]) if n else \
        np.zeros(0, dtype=np.int64)
    row_of = np.repeat(rows, counts)                 # row of light entry j
    inner = np.arange(total, dtype=np.int64) - cum0[row_of] \
        if total else np.zeros(0, dtype=np.int64)
    slot = row_of * w + inner                        # row-major slab slot
    light = np.empty(n + 3 * total, dtype=np.int64)
    light[rows + 3 * cum0] = y_base + (rows * ebytes) // lb
    body = row_of + 1 + 3 * np.arange(total, dtype=np.int64)
    light[body] = lval_base + (slot * ebytes) // lb
    light[body + 1] = lidx_base + (slot * ibytes) // lb
    light[body + 2] = x_base + (lidx[slot] * ebytes) // lb

    # heavy launch: per nonzero: value, row id, col id, x[col] (ascending)
    p = np.arange(hnnz, dtype=np.int64)
    heavy = np.empty(4 * hnnz, dtype=np.int64)
    heavy[0::4] = hval_base + (p * ebytes) // lb
    heavy[1::4] = hrow_base + (p * ibytes) // lb
    heavy[2::4] = hcol_base + (p * ibytes) // lb
    heavy[3::4] = x_base + (hcols * ebytes) // lb
    # carry merge: one y combine per distinct heavy row
    hr = np.unique(hrows)
    tail = y_base + (hr * ebytes) // lb
    return np.concatenate([light, heavy, tail])


def format_address_trace(csr, format_name: str, machine,
                         container=None) -> np.ndarray:
    """Format-aware demand trace for a planned matrix.

    'hyb' plans get the split light/heavy stream (`hyb_address_trace` of
    the plan's container, rebuilt from the CSR if absent); every other
    format -- including 'csr-seg', whose win is thread balance, not
    stream shape -- replays the flat CSR stream of `spmv_address_trace`.
    """
    if format_name == "hyb":
        from repro.core.formats import HYB

        if not isinstance(container, HYB):
            container = HYB.from_csr(csr)
        lengths = csr.row_lengths()
        light_counts = np.where(lengths > container.threshold, 0, lengths) \
            if len(lengths) else lengths
        return hyb_address_trace(container, machine,
                                 light_counts=light_counts)
    return spmv_address_trace(csr, machine)


def _y_region_base(csr, format_name: str, machine, container=None) -> int:
    """First line id of the y region in `format_address_trace`'s layout
    for this matrix -- the overlay pass's y combines must land on the
    *same* lines the base kernel writes, or the simulator would price
    them as cold compulsory misses they are not."""
    lb = machine.line_bytes
    ebytes, ibytes = machine.elem_bytes, machine.idx_bytes
    if format_name == "hyb":
        from repro.core.formats import HYB

        if not isinstance(container, HYB):
            container = HYB.from_csr(csr)
        n, w = container.n_rows, container.light_width
        x_lines = -(-container.n_cols * ebytes // lb)
        lval_lines = -(-n * w * ebytes // lb)
        lidx_lines = -(-n * w * ibytes // lb)
        return x_lines + 16 + lval_lines + 16 + lidx_lines + 16
    n, nnz = csr.n_rows, csr.nnz
    x_lines = -(-n * ebytes // lb)
    val_lines = -(-nnz * ebytes // lb)
    idx_lines = -(-nnz * ibytes // lb)
    ptr_lines = -(-(n + 1) * ibytes // lb)
    return x_lines + 16 + val_lines + 16 + idx_lines + 16 + ptr_lines + 16


def overlay_address_trace(csr, format_name: str, rows, cols, machine,
                          container=None) -> np.ndarray:
    """Demand stream of an overlaid plan: the base plan's format trace
    followed by the delta pass, priced as a column-sorted COO stream.

    The overlay executes after the planned kernel: per delta nonzero it
    reads the delta value / row id / col id (fresh sequential regions
    past the base layout, 16-line guards) and gathers x[col]; one y
    combine per distinct delta row then lands on the base layout's y
    region.  Column-sorting the stream makes the x gathers ascend --
    the same locality argument as the hybrid heavy stream -- which is
    why a small overlay prices at a near-streaming marginal cost rather
    than a second random walk over x.  An empty delta returns the base
    trace unchanged."""
    base = format_address_trace(csr, format_name, machine,
                                container=container)
    rows = np.asarray(rows, dtype=np.int64).reshape(-1)
    cols = np.asarray(cols, dtype=np.int64).reshape(-1)
    k = int(rows.shape[0])
    if k == 0:
        return base
    lb = machine.line_bytes
    ebytes, ibytes = machine.elem_bytes, machine.idx_bytes
    order = np.lexsort((rows, cols))             # column-sorted COO
    r, c = rows[order], cols[order]

    dval_base = (int(base.max()) + 17) if base.size else 0
    dval_lines = -(-k * ebytes // lb)
    drow_base = dval_base + dval_lines + 16
    drow_lines = -(-k * ibytes // lb)
    dcol_base = drow_base + drow_lines + 16

    p = np.arange(k, dtype=np.int64)
    delta = np.empty(4 * k, dtype=np.int64)
    delta[0::4] = dval_base + (p * ebytes) // lb
    delta[1::4] = drow_base + (p * ibytes) // lb
    delta[2::4] = dcol_base + (p * ibytes) // lb
    delta[3::4] = (c * ebytes) // lb             # x region starts at line 0
    y_base = _y_region_base(csr, format_name, machine, container=container)
    tail = y_base + (np.unique(r) * ebytes) // lb
    return np.concatenate([base, delta, tail])


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """Declarative description of a hierarchy (what sweeps iterate over)."""

    l2_bytes: Optional[int] = None       # None -> machine default
    l3_bytes: Optional[int] = None
    ways: Optional[int] = None           # L2 associativity; None -> full
    l3_ways: Optional[int] = None        # L3 associativity; None -> full
    prefetcher: bool = True
    victim_entries: int = 0
    miss_entries: int = 0
    stream_buffers: int = 0
    stream_depth: int = 4

    def instantiate(self, machine) -> Hierarchy:
        mechs: List = []
        if self.victim_entries:
            mechs.append(VictimCache(self.victim_entries))
        if self.miss_entries:
            mechs.append(MissCache(self.miss_entries))
        if self.stream_buffers:
            mechs.append(StreamBuffers(self.stream_buffers,
                                       self.stream_depth))
        return Hierarchy.build(
            machine, ways=self.ways, l2_bytes=self.l2_bytes,
            l3_bytes=self.l3_bytes, l3_ways=self.l3_ways,
            prefetcher=self.prefetcher, l2_mechanisms=mechs)

    def label(self) -> str:
        parts = []
        if self.victim_entries:
            parts.append(f"victim{self.victim_entries}")
        if self.miss_entries:
            parts.append(f"miss{self.miss_entries}")
        if self.stream_buffers:
            parts.append(f"stream{self.stream_buffers}x{self.stream_depth}")
        if self.ways is not None:
            parts.append(f"{self.ways}way")
        if not self.prefetcher:
            parts.append("nopf")
        return "+".join(parts) if parts else "baseline"
