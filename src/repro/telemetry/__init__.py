"""repro.telemetry — pluggable memory-hierarchy simulation + topdown metrics.

The measurement layer for the paper's §V architecture proposals and the
multithreaded sweeps built on them: compose set-associative levels with
victim caches, miss caches, and stream buffers, count named hardware
events, and roll them up into a topdown metric tree.  Sweeps cross four
axes — geometry × mechanism × reordering (`repro.reorder` strategies
applied before tracing) × threads (`scaling_sweep`, which drives the
`repro.parallel` shared-LLC engine) — plus the whole-analytic axis
(`graph_sweep`: per-iteration replay of `repro.graph` driver runs, so
the FD/R-MAT gap is measured end-to-end, compounding included).

  events     named hardware-event counters (L2_DEMAND_MISS, VICTIM_HIT, ...)
  hierarchy  set-assoc. caches + prefetcher + §V mechanisms; trace replay
  topdown    staged cycle attribution (Retiring/Frontend/Backend-*,
             bit-exact stage sums) + the VTune-style metric tree
  sweep      geometry x mechanism x reorder x thread sweep harness
  runner     sharded, checkpointed, resumable sweep execution
  report     CSV / JSON / markdown rendering + the bottom-line tables:
             gap_report (hardware), reorder_gap_report (software),
             scaling_report / scaling_gap_report (thread scaling),
             graph_report / graph_gap_report (whole analytics)
"""
from . import events, hierarchy, report, runner, sweep, topdown
from .events import EventCounters, known_events, register_event
from .hierarchy import (CacheLevel, Hierarchy, HierarchySpec, MissCache,
                        SequentialPrefetcher, SetAssocCache, StreamBuffers,
                        VictimCache, format_address_trace, hyb_address_trace,
                        overlay_address_trace, spmv_address_trace)
from .report import (graph_gap_report, graph_report, plan_cache_report,
                     scaling_gap_report, scaling_report)
from .runner import (SweepCell, SweepConfig, execute_cells, graph_cells,
                     mech_cells, scaling_cells, sort_cells)
from .sweep import GraphPoint, ScalingPoint, graph_sweep, scaling_sweep
from .topdown import (STAGE_FIELDS, MetricNode, TopdownStages,
                      machine_stages, stage_cycles, topdown_tree,
                      topdown_summary)

__all__ = [
    "events", "hierarchy", "report", "runner", "sweep", "topdown",
    "EventCounters", "known_events", "register_event",
    "CacheLevel", "Hierarchy", "HierarchySpec", "MissCache",
    "SequentialPrefetcher", "SetAssocCache", "StreamBuffers", "VictimCache",
    "spmv_address_trace", "format_address_trace", "hyb_address_trace",
    "overlay_address_trace",
    "MetricNode", "topdown_tree", "topdown_summary",
    "STAGE_FIELDS", "TopdownStages", "stage_cycles", "machine_stages",
    "SweepCell", "SweepConfig", "execute_cells", "mech_cells",
    "scaling_cells", "graph_cells", "sort_cells",
    "ScalingPoint", "scaling_sweep", "scaling_report", "scaling_gap_report",
    "GraphPoint", "graph_sweep", "graph_report", "graph_gap_report",
    "plan_cache_report",
]
