"""repro.telemetry — pluggable memory-hierarchy simulation + topdown metrics.

The measurement layer for the paper's §V architecture proposals: instead of
one hard-coded fully-associative LRU hierarchy, compose set-associative
levels with victim caches, miss caches, and stream buffers, count named
hardware events, and roll them up into a topdown metric tree.

  events     named hardware-event counters (L2_DEMAND_MISS, VICTIM_HIT, ...)
  hierarchy  set-assoc. caches + prefetcher + §V mechanisms; trace replay
  topdown    staged metric tree (memory-bound -> L3/DRAM-bound, MPKI family)
  sweep      geometry x mechanism x matrix-kind sweep harness
  report     CSV / JSON / markdown rendering + FD-vs-R-MAT gap report
"""
from . import events, hierarchy, report, sweep, topdown
from .events import EventCounters, known_events, register_event
from .hierarchy import (CacheLevel, Hierarchy, HierarchySpec, MissCache,
                        SequentialPrefetcher, SetAssocCache, StreamBuffers,
                        VictimCache, spmv_address_trace)
from .topdown import MetricNode, topdown_tree, topdown_summary

__all__ = [
    "events", "hierarchy", "report", "sweep", "topdown",
    "EventCounters", "known_events", "register_event",
    "CacheLevel", "Hierarchy", "HierarchySpec", "MissCache",
    "SequentialPrefetcher", "SetAssocCache", "StreamBuffers", "VictimCache",
    "spmv_address_trace", "MetricNode", "topdown_tree", "topdown_summary",
]
