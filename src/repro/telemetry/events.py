"""Hardware-event counter registry for the memory-hierarchy simulator.

Models the VTune event set the paper collects (§III: L2/L3 demand misses,
prefetcher fills, stall cycles) plus the counters needed to evaluate the
§V candidate mechanisms (victim cache, miss cache, stream buffers).  Every
event is a named, documented counter so sweeps and reports can refer to
`L2_DEMAND_MISS` instead of positional tuple fields, and new mechanisms
can register their own events without touching the core.
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping

# ---------------------------------------------------------------------------
# Event names (module-level constants so call sites are grep-able)
# ---------------------------------------------------------------------------

ACCESS = "ACCESS"                      # demand accesses issued by the kernel
L2_DEMAND_HIT = "L2_DEMAND_HIT"
L2_DEMAND_MISS = "L2_DEMAND_MISS"
L3_DEMAND_HIT = "L3_DEMAND_HIT"
L3_DEMAND_MISS = "L3_DEMAND_MISS"      # demand lines fetched from DRAM
L2_PREFETCH_FILL = "L2_PREFETCH_FILL"  # lines the HW prefetcher pulled to L2
L2_PREFETCH_HIT = "L2_PREFETCH_HIT"    # first demand hit on a prefetched line
VICTIM_PROBE = "VICTIM_PROBE"
VICTIM_HIT = "VICTIM_HIT"              # L2 miss rescued by the victim cache
MISS_CACHE_PROBE = "MISS_CACHE_PROBE"
MISS_CACHE_HIT = "MISS_CACHE_HIT"      # L2 miss rescued by the miss cache
STREAM_PROBE = "STREAM_PROBE"
STREAM_HIT = "STREAM_HIT"              # L2 miss served at a stream-buffer head
STREAM_ALLOC = "STREAM_ALLOC"          # stream buffers (re)allocated
STREAM_FILL = "STREAM_FILL"            # lines fetched into stream buffers

_REGISTRY: Dict[str, str] = {
    ACCESS: "demand accesses issued by the kernel trace",
    L2_DEMAND_HIT: "demand accesses that hit in L2",
    L2_DEMAND_MISS: "demand accesses that missed L2",
    L3_DEMAND_HIT: "L2 misses that hit in L3",
    L3_DEMAND_MISS: "demand lines fetched from DRAM",
    L2_PREFETCH_FILL: "lines the sequential prefetcher filled into L2",
    L2_PREFETCH_HIT: "first demand hit on a line brought in by prefetch",
    VICTIM_PROBE: "victim-cache probes (one per L2 miss when attached)",
    VICTIM_HIT: "L2 misses served by swapping a line back from the victim cache",
    MISS_CACHE_PROBE: "miss-cache probes (one per L2 miss when attached)",
    MISS_CACHE_HIT: "L2 misses served by the miss cache",
    STREAM_PROBE: "stream-buffer probes (one per L2 miss when attached)",
    STREAM_HIT: "L2 misses served at the head of a stream buffer",
    STREAM_ALLOC: "stream buffers allocated/replaced on miss",
    STREAM_FILL: "lines fetched from memory into stream buffers",
}


def register_event(name: str, description: str) -> str:
    """Register a new named event (idempotent); returns the name."""
    _REGISTRY.setdefault(name, description)
    return name


def known_events() -> Mapping[str, str]:
    return dict(_REGISTRY)


def describe(name: str) -> str:
    return _REGISTRY.get(name, "(unregistered event)")


class EventCounters:
    """A bag of named monotone counters.

    Unknown names are allowed (mechanisms may register events lazily), but
    `validate()` flags anything never registered -- useful in tests.
    """

    __slots__ = ("counts",)

    def __init__(self, initial: Mapping[str, int] | None = None):
        self.counts: Dict[str, int] = dict(initial or {})

    def inc(self, name: str, n: int = 1) -> None:
        self.counts[name] = self.counts.get(name, 0) + n

    def __getitem__(self, name: str) -> int:
        return self.counts.get(name, 0)

    def get(self, name: str, default: int = 0) -> int:
        return self.counts.get(name, default)

    def as_dict(self) -> Dict[str, int]:
        return dict(self.counts)

    def reset(self) -> None:
        self.counts.clear()

    def merge(self, other: "EventCounters") -> "EventCounters":
        out = EventCounters(self.counts)
        for k, v in other.counts.items():
            out.inc(k, v)
        return out

    def validate(self) -> Iterable[str]:
        """Names present in the counters but never registered."""
        return sorted(k for k in self.counts if k not in _REGISTRY)

    # -- derived conveniences used all over the reports ---------------------

    def rate(self, num: str, den: str) -> float:
        d = self.counts.get(den, 0)
        return self.counts.get(num, 0) / d if d else 0.0

    def per_kinst(self, name: str, kinst: float) -> float:
        return self.counts.get(name, 0) / kinst if kinst else 0.0

    def __eq__(self, other) -> bool:
        """Value equality over nonzero counts (zero entries are equivalent
        to absent ones), so a replayed and a checkpoint-restored counter
        bag compare equal.  Instances stay unhashable (mutable)."""
        if not isinstance(other, EventCounters):
            return NotImplemented
        a = {k: v for k, v in self.counts.items() if v}
        b = {k: v for k, v in other.counts.items() if v}
        return a == b

    __hash__ = None

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v}" for k, v in sorted(self.counts.items()))
        return f"EventCounters({inner})"
