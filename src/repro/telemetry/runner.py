"""Sharded, checkpointed, resumable sweep execution.

The sweep harness's scaling problem is grid size: ROADMAP items 1 and 4
need (matrix x geometry x reorder x format x threads x partition x
mechanism) grids far larger than a serial loop finishes in one sitting.
This module turns a sweep into:

  1. a deterministic, **sorted** cell enumeration (`mech_cells`,
     `scaling_cells`, `graph_cells` -> `SweepCell`), so checkpoint keys
     and shard assignment are stable across runs and axis orderings;
  2. sharded execution across worker processes (`execute_cells` with
     `workers=N`, `concurrent.futures` over a spawn context -- jax is
     not fork-safe);
  3. incremental checkpointing of completed cells through
     `repro.checkpoint.CheckpointManager` (`ckpt_dir=`), each point
     serialized to a canonical JSON payload;
  4. resume: a re-run with the same `ckpt_dir` loads completed cells and
     executes only the remainder -- the merged grid is **bit-identical**
     to an uninterrupted run, because every cell function is a pure
     deterministic function of (cell, config) and the payload encoding
     round-trips exactly (`tests/test_sweep_runner.py` pins this).

`sweep.run_sweep` / `scaling_sweep` / `graph_sweep` are thin clients;
`python -m repro.telemetry.runner` is the operational entry point
(`benchmarks/run.py --workers/--resume` forwards here).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import multiprocessing
import os
from concurrent.futures import ProcessPoolExecutor, as_completed
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.cache_model import SANDY_BRIDGE, MachineModel

from .events import EventCounters
from .hierarchy import HierarchySpec
from .topdown import TopdownStages, TopdownSummary

# ---------------------------------------------------------------------------
# Cells: the unit of sharding, checkpointing and resume
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, order=True)
class SweepCell:
    """One grid cell, by label.  Labels resolve against `SweepConfig`
    (mechanism -> `HierarchySpec`, reorder -> strategy callable), so a
    cell is a small, picklable, hashable value whose string `key()` is
    stable across processes and runs -- the checkpoint key.
    """

    sweep: str                # 'mech' | 'scaling' | 'graph' | 'label'
    kind: str                 # 'fd' | 'rmat' | (label: costmodel.LABEL_KINDS)
    log2n: int
    reorder: str = "none"
    format: str = ""          # graph: pinned container format ('' = auto)
    threads: int = 1
    partition: str = ""       # scaling: 'equal' | 'balanced' | 'merge'
    mechanism: str = ""       # mech: label into SweepConfig.mechanisms;
                              # label: costmodel.LABEL_SPECS geometry key
    analytic: str = ""        # graph: driver name

    def key(self) -> str:
        return "|".join([
            self.sweep, self.kind, str(self.log2n), self.reorder,
            self.format or "-", str(self.threads), self.partition or "-",
            self.mechanism or "-", self.analytic or "-"])


def sort_cells(cells: Sequence[SweepCell]) -> List[SweepCell]:
    """Canonical execution order: deduplicated and sorted (dataclass field
    order), independent of the order axes were listed in.  Consecutive
    cells share (kind, size, reorder), so per-process plan/trace memos
    hit; checkpoint keys and shard chunks follow this order."""
    return sorted(set(cells))


def mech_cells(log2ns: Sequence[int], kinds: Sequence[str],
               mechanisms: Sequence[str] | Mapping[str, object],
               threads_list: Sequence[int] = (1,),
               reorderings: Sequence[str] | Mapping[str, object] = ("none",),
               ) -> List[SweepCell]:
    """Enumerate `run_sweep`'s grid (mechanism labels x the matrix axes)."""
    return sort_cells([
        SweepCell(sweep="mech", kind=k, log2n=int(n), reorder=r,
                  threads=int(t), mechanism=m)
        for k in kinds for n in log2ns for r in list(reorderings)
        for t in set(threads_list) for m in list(mechanisms)])


def scaling_cells(log2ns: Sequence[int], kinds: Sequence[str],
                  threads_list: Sequence[int],
                  partition: str = "equal",
                  reorderings: Sequence[str] | Mapping[str, object] = ("none",),
                  ) -> List[SweepCell]:
    """Enumerate `scaling_sweep`'s grid (the thread axis)."""
    return sort_cells([
        SweepCell(sweep="scaling", kind=k, log2n=int(n), reorder=r,
                  threads=int(t), partition=partition)
        for k in kinds for n in log2ns for r in list(reorderings)
        for t in set(threads_list)])


def graph_cells(log2ns: Sequence[int], kinds: Sequence[str],
                analytics: Sequence[str],
                format: Optional[str] = None) -> List[SweepCell]:
    """Enumerate `graph_sweep`'s grid (whole-analytic cells)."""
    return sort_cells([
        SweepCell(sweep="graph", kind=k, log2n=int(n), analytic=a,
                  format=format or "")
        for k in kinds for n in log2ns for a in analytics])


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Everything a worker needs to resolve and run a cell (picklable:
    strategies are module-level callables, specs are frozen dataclasses).
    `None` mappings fall back to the sweep module's defaults."""

    machine: MachineModel = SANDY_BRIDGE
    sweeps: int = 2
    seed: int = 0
    mechanisms: Optional[Mapping[str, HierarchySpec]] = None
    reorderings: Optional[Mapping[str, object]] = None
    parallel_spec: Optional[object] = None       # repro.parallel.ParallelSpec
    hier_spec: Optional[HierarchySpec] = None    # graph per-iteration replay
    max_iters: int = 64
    graph_format: Optional[str] = None


def run_cell(cell: SweepCell, cfg: SweepConfig):
    """Execute one cell (pure, deterministic).  Returns the sweep point."""
    from . import sweep as sw

    reorderings = (dict(cfg.reorderings) if cfg.reorderings is not None
                   else {"none": None})
    if cell.sweep == "mech":
        mechanisms = (dict(cfg.mechanisms) if cfg.mechanisms is not None
                      else sw.MECHANISMS)
        return sw.run_mech_cell(
            cell.kind, cell.log2n, cell.reorder,
            reorderings[cell.reorder], cell.threads, cell.mechanism,
            mechanisms[cell.mechanism], machine=cfg.machine,
            sweeps=cfg.sweeps, seed=cfg.seed)
    if cell.sweep == "scaling":
        return sw.run_scaling_cell(
            cell.kind, cell.log2n, cell.reorder,
            reorderings[cell.reorder], cell.partition, cell.threads,
            spec=cfg.parallel_spec, machine=cfg.machine,
            sweeps=cfg.sweeps, seed=cfg.seed)
    if cell.sweep == "graph":
        return sw.run_graph_cell(
            cell.kind, cell.log2n, cell.analytic, spec=cfg.hier_spec,
            machine=cfg.machine, seed=cfg.seed, max_iters=cfg.max_iters,
            format=cell.format or cfg.graph_format or None)
    if cell.sweep == "label":
        # cost-model training rows: replay-oracle throughput labels
        # (the spec geometry rides the free `mechanism` field)
        from repro.plan import costmodel

        return costmodel.run_label_cell(
            cell.kind, cell.log2n, cell.reorder, cell.threads,
            spec_label=cell.mechanism, machine=cfg.machine,
            seed=cfg.seed, sweeps=cfg.sweeps)
    raise ValueError(f"unknown sweep family {cell.sweep!r}")


# ---------------------------------------------------------------------------
# Point payloads: canonical JSON, exact round-trip
# ---------------------------------------------------------------------------
# json round-trips Python floats exactly (shortest-repr serialization), so
# decode(encode(p)) == p field-for-field and re-encoding a decoded point
# reproduces the byte payload -- which is what lets resumed grids be
# compared bit-for-bit against uninterrupted ones.


def _plain(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, (np.bool_,)):
        return bool(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"cannot serialize {type(o)!r}")


def encode_point(p) -> bytes:
    """Canonical JSON payload for a sweep point (sorted keys, utf-8)."""
    from repro.plan.costmodel import LabelPoint

    from .sweep import GraphPoint, ScalingPoint, SweepPoint

    if isinstance(p, LabelPoint):
        tag, d = "label", dataclasses.asdict(p)
    elif isinstance(p, SweepPoint):
        tag, d = "mech", {
            "kind": p.kind, "log2n": p.log2n, "nnz": p.nnz,
            "threads": p.threads, "mechanism": p.mechanism,
            "reorder": p.reorder, "spec": dataclasses.asdict(p.spec),
            "counters": p.counters.as_dict(),
            "summary": p.summary.as_dict()}
    elif isinstance(p, ScalingPoint):
        tag, d = "scaling", {
            "kind": p.kind, "log2n": p.log2n, "nnz": p.nnz,
            "threads": p.threads, "reorder": p.reorder,
            "partition": p.partition, "imbalance": p.imbalance,
            "speedup": p.speedup, "efficiency": p.efficiency,
            "metrics": dataclasses.asdict(p.metrics)}
    elif isinstance(p, GraphPoint):
        tag, d = "graph", {
            "kind": p.kind, "log2n": p.log2n, "nnz": p.nnz,
            "analytic": p.analytic, "semiring": p.semiring,
            "n_iters": p.n_iters, "converged": p.converged,
            "format_name": p.format_name,
            "iters": [s.as_dict() for s in p.iters]}
    else:
        raise TypeError(f"cannot encode {type(p)!r}")
    return json.dumps({"t": tag, "d": d}, sort_keys=True,
                      default=_plain).encode("utf-8")


def decode_point(blob: bytes):
    """Inverse of `encode_point` (value-exact)."""
    from repro.parallel.scaling import ParallelMetrics

    from .sweep import GraphPoint, ScalingPoint, SweepPoint

    obj = json.loads(blob.decode("utf-8"))
    tag, d = obj["t"], obj["d"]
    if tag == "label":
        from repro.plan.costmodel import LabelPoint

        return LabelPoint(
            kind=d["kind"], log2n=int(d["log2n"]), seed=int(d["seed"]),
            reorder=d["reorder"], threads=int(d["threads"]),
            spec=d["spec"], nnz=int(d["nnz"]), gflops=float(d["gflops"]),
            time_s=float(d["time_s"]),
            features=tuple(float(v) for v in d["features"]))
    if tag == "mech":
        return SweepPoint(
            kind=d["kind"], log2n=int(d["log2n"]), nnz=int(d["nnz"]),
            threads=int(d["threads"]), mechanism=d["mechanism"],
            reorder=d["reorder"], spec=HierarchySpec(**d["spec"]),
            counters=EventCounters({k: int(v)
                                    for k, v in d["counters"].items()}),
            summary=TopdownSummary(**d["summary"]))
    if tag == "scaling":
        m = dict(d["metrics"])
        m["nnz_per_thread"] = tuple(int(v) for v in m["nnz_per_thread"])
        m["cycles_per_thread"] = tuple(float(v)
                                       for v in m["cycles_per_thread"])
        m["l2_mpki"] = tuple(float(v) for v in m["l2_mpki"])
        m["llc_mpki"] = tuple(float(v) for v in m["llc_mpki"])
        m["stages"] = TopdownStages(**m["stages"])
        m["thread_stages"] = tuple(TopdownStages(**s)
                                   for s in m["thread_stages"])
        return ScalingPoint(
            kind=d["kind"], log2n=int(d["log2n"]), nnz=int(d["nnz"]),
            threads=int(d["threads"]), reorder=d["reorder"],
            partition=d["partition"], imbalance=float(d["imbalance"]),
            speedup=float(d["speedup"]), efficiency=float(d["efficiency"]),
            metrics=ParallelMetrics(**m))
    if tag == "graph":
        return GraphPoint(
            kind=d["kind"], log2n=int(d["log2n"]), nnz=int(d["nnz"]),
            analytic=d["analytic"], semiring=d["semiring"],
            n_iters=int(d["n_iters"]), converged=bool(d["converged"]),
            format_name=d["format_name"],
            iters=tuple(TopdownSummary(**s) for s in d["iters"]))
    raise ValueError(f"unknown payload tag {tag!r}")


# ---------------------------------------------------------------------------
# Execution: serial or sharded, with incremental checkpoint + resume
# ---------------------------------------------------------------------------


def _manager(ckpt_dir: str):
    from repro.checkpoint import CheckpointManager

    return CheckpointManager(ckpt_dir, keep=2)


def _load_completed(mgr) -> Dict[str, bytes]:
    """key -> payload from the newest committed checkpoint (empty if none)."""
    try:
        tree, _ = mgr.restore_any()
    except FileNotFoundError:
        return {}
    cells = tree.get("cells", {})
    return {k: np.asarray(v, dtype=np.uint8).tobytes()
            for k, v in cells.items()}


def _save(mgr, done: Mapping[str, bytes]) -> None:
    """Checkpoint the completed-cell map; step = cell count (monotone --
    saves only happen when new cells completed)."""
    tree = {"cells": {k: np.frombuffer(v, dtype=np.uint8)
                      for k, v in done.items()}}
    mgr.save(len(done), tree)


def _run_chunk(chunk: List[SweepCell],
               cfg: SweepConfig) -> List[Tuple[str, bytes]]:
    """Worker entry: run a contiguous chunk, return (key, payload) pairs."""
    return [(cell.key(), encode_point(run_cell(cell, cfg)))
            for cell in chunk]


def _chunks(todo: List[SweepCell], workers: int) -> List[List[SweepCell]]:
    """Contiguous slices of the sorted order (so a chunk stays on one
    plan), at least ~4 chunks per worker for checkpoint granularity."""
    if not todo:
        return []
    per = max(1, math.ceil(len(todo) / (workers * 4)))
    return [todo[i:i + per] for i in range(0, len(todo), per)]


def execute_cells(cells: Sequence[SweepCell],
                  cfg: Optional[SweepConfig] = None,
                  workers: int = 1,
                  ckpt_dir: Optional[str] = None,
                  resume: bool = True,
                  checkpoint_every: int = 8,
                  max_cells: Optional[int] = None) -> List:
    """Run a cell list to completion and return its points in canonical
    (sorted, deduplicated) cell order.

    `workers > 1` shards the remaining cells across spawn-context worker
    processes; `ckpt_dir` checkpoints completed cells incrementally
    (every `checkpoint_every` serial cells / after every parallel chunk)
    and, with `resume=True`, skips cells already committed there.
    `max_cells` stops after that many *new* cells -- the deterministic
    "interrupted run" used by tests and the CI resume smoke -- returning
    only the points completed so far.

    Identical results regardless of workers, interruptions, or the order
    axes were listed in: cells are pure functions of (cell, cfg), the
    enumeration is sorted, and payloads round-trip exactly.
    """
    cfg = cfg if cfg is not None else SweepConfig()
    cells = sort_cells(cells)
    mgr = _manager(ckpt_dir) if ckpt_dir else None
    done: Dict[str, bytes] = \
        _load_completed(mgr) if (mgr is not None and resume) else {}
    known = {c.key() for c in cells}
    todo = [c for c in cells if c.key() not in done]
    if max_cells is not None:
        todo = todo[:max_cells]

    if workers <= 1 or len(todo) <= 1:
        fresh = 0
        for cell in todo:
            done[cell.key()] = encode_point(run_cell(cell, cfg))
            fresh += 1
            if mgr is not None and fresh % max(checkpoint_every, 1) == 0:
                _save(mgr, done)
    else:
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as pool:
            futs = [pool.submit(_run_chunk, chunk, cfg)
                    for chunk in _chunks(todo, workers)]
            for fut in as_completed(futs):
                for key, blob in fut.result():
                    done[key] = blob
                if mgr is not None:
                    _save(mgr, done)

    if mgr is not None:
        if todo:
            _save(mgr, done)
        mgr.wait()
    return [decode_point(done[c.key()]) for c in cells if c.key() in done
            and c.key() in known]


# ---------------------------------------------------------------------------
# CLI: `python -m repro.telemetry.runner` (what CI's sweep-resume job runs)
# ---------------------------------------------------------------------------


def _int_list(s: str) -> List[int]:
    return [int(v) for v in s.split(",") if v]


def _str_list(s: str) -> List[str]:
    return [v for v in s.split(",") if v]


def build_cells(args) -> Tuple[List[SweepCell], SweepConfig]:
    """Translate CLI arguments into (cells, config)."""
    from repro.parallel import ParallelSpec

    reorderings: Dict[str, object] = {}
    for label in _str_list(args.reorders):
        if label == "none":
            reorderings[label] = None
        else:
            from repro.reorder import STRATEGIES

            reorderings[label] = STRATEGIES[label]
    pspec = (ParallelSpec(l2_bytes=16 * 1024, llc_bytes=64 * 1024)
             if args.scaled else ParallelSpec())
    kinds = _str_list(args.kinds)
    log2ns = _int_list(args.log2ns)
    threads = _int_list(args.threads)
    if args.sweep == "mech":
        from .sweep import MECHANISMS

        mechs = ({m: MECHANISMS[m] for m in _str_list(args.mechanisms)}
                 if args.mechanisms else dict(MECHANISMS))
        cells = mech_cells(log2ns, kinds, mechs, threads_list=threads,
                           reorderings=reorderings)
        cfg = SweepConfig(sweeps=args.sweeps, seed=args.seed,
                          mechanisms=mechs, reorderings=reorderings)
    elif args.sweep == "graph":
        cells = graph_cells(log2ns, kinds,
                            analytics=_str_list(args.analytics))
        cfg = SweepConfig(seed=args.seed)
    else:
        cells = scaling_cells(log2ns, kinds, threads_list=threads,
                              partition=args.partition,
                              reorderings=reorderings)
        cfg = SweepConfig(sweeps=args.sweeps, seed=args.seed,
                          reorderings=reorderings, parallel_spec=pspec)
    return cells, cfg


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="sharded resumable sweep runner "
                    "(see repro.telemetry.sweep for the grids)")
    ap.add_argument("--sweep", choices=("mech", "scaling", "graph"),
                    default="scaling")
    ap.add_argument("--kinds", default="fd,rmat")
    ap.add_argument("--log2ns", default="8")
    ap.add_argument("--threads", default="1,2,4")
    ap.add_argument("--partition", default="balanced",
                    choices=("equal", "balanced", "merge"))
    ap.add_argument("--reorders", default="none")
    ap.add_argument("--mechanisms", default="",
                    help="comma list of MECHANISMS labels (mech sweep)")
    ap.add_argument("--analytics", default="pagerank,bfs")
    ap.add_argument("--sweeps", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scaled", action="store_true",
                    help="shrunken caches (the 2^12 'scaled' cell geometry)")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--ckpt", default=None, help="checkpoint directory")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore any existing checkpoint in --ckpt")
    ap.add_argument("--max-cells", type=int, default=None,
                    help="stop after N new cells (simulated interruption)")
    ap.add_argument("--checkpoint-every", type=int, default=8)
    ap.add_argument("--csv", action="store_true", help="print the report")
    ap.add_argument("--verify", action="store_true",
                    help="recompute the grid serially in-process and demand "
                         "byte-identical payloads (exit 1 on mismatch)")
    args = ap.parse_args(argv)

    cells, cfg = build_cells(args)
    points = execute_cells(cells, cfg, workers=args.workers,
                           ckpt_dir=args.ckpt, resume=not args.no_resume,
                           checkpoint_every=args.checkpoint_every,
                           max_cells=args.max_cells)
    print(f"[runner] {args.sweep} sweep: {len(points)}/{len(cells)} cells "
          f"complete (workers={args.workers}, "
          f"ckpt={args.ckpt or 'none'})")
    if args.csv and points:
        from . import report

        render = {"mech": report.to_csv, "scaling": report.scaling_report,
                  "graph": report.graph_report}[args.sweep]
        print(render(points))
    if args.verify:
        if len(points) < len(cells):
            print("[runner] verify: grid incomplete, run again without "
                  "--max-cells first")
            return 1
        fresh = execute_cells(cells, cfg, workers=1, ckpt_dir=None)
        got = [encode_point(p) for p in points]
        want = [encode_point(p) for p in fresh]
        if got != want:
            bad = sum(1 for g, w in zip(got, want) if g != w)
            print(f"[runner] verify FAILED: {bad} cells differ from the "
                  f"serial recomputation")
            return 1
        print(f"[runner] verify OK: {len(points)} cells byte-identical to "
              f"serial recomputation")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
