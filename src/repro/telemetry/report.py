"""Rendering for telemetry sweeps: CSV, JSON, markdown, and the gap report.

The gap report is the paper's §V bottom line: for each candidate mechanism,
how much of the FD-vs-R-MAT performance gap (estimated GFLOPS ratio, L2
MPKI ratio) does it close relative to the baseline hierarchy?
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence

from .sweep import GraphPoint, ScalingPoint, SweepPoint


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def to_csv(points: Sequence[SweepPoint], title: str = "telemetry") -> str:
    lines = [f"# {title}", ",".join(SweepPoint.header())]
    for p in points:
        lines.append(",".join(_fmt(v) for v in p.row()))
    return "\n".join(lines)


def to_json(points: Sequence[SweepPoint]) -> str:
    out = []
    for p in points:
        out.append({
            "kind": p.kind, "log2n": p.log2n, "nnz": p.nnz,
            "threads": p.threads, "reorder": p.reorder,
            "mechanism": p.mechanism,
            "spec": p.spec.label(),
            "summary": p.summary.as_dict(),
            "counters": p.counters.as_dict(),
        })
    return json.dumps(out, indent=2)


def to_markdown(points: Sequence[SweepPoint],
                columns: Sequence[str] = ("l2_mpki", "l3_mpki",
                                          "pf_coverage", "mech_served_frac",
                                          "dram_bound", "gflops_est")) -> str:
    head = ["kind", "log2n", "threads", "mechanism"] + list(columns)
    lines = ["| " + " | ".join(head) + " |",
             "|" + "|".join("---" for _ in head) + "|"]
    for p in points:
        row = [p.kind, str(p.log2n), str(p.threads), p.mechanism]
        row += [_fmt(getattr(p.summary, c)) for c in columns]
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def _index(points: Iterable[SweepPoint]) -> Dict:
    by = {}
    for p in points:
        by[(p.kind, p.log2n, p.threads, p.mechanism)] = p
    return by


def gap_report(points: Sequence[SweepPoint]) -> str:
    """Per (size, threads, mechanism): the FD / R-MAT gap and how much of
    the baseline gap the mechanism closes.

    gap        = fd.gflops_est / rmat.gflops_est       (paper: ~5x at 2^24)
    closed     = 1 - (gap_mech - 1) / (gap_base - 1)   (1.0 -> gap gone)

    Reordered points are excluded -- this report isolates the hardware
    mechanisms; `reorder_gap_report` covers the software side.
    """
    points = [p for p in points if p.reorder == "none"]
    by = _index(points)
    keys = sorted({(p.log2n, p.threads) for p in points})
    mechs = []
    for p in points:
        if p.mechanism not in mechs:
            mechs.append(p.mechanism)
    lines = ["# FD vs R-MAT gap per mechanism",
             "log2n,threads,mechanism,fd_gflops,rmat_gflops,gap,"
             "rmat_l2_mpki,fd_bound,rmat_bound,gap_closed_vs_baseline"]
    for (log2n, threads) in keys:
        base_gap = None
        base = (by.get(("fd", log2n, threads, "baseline")),
                by.get(("rmat", log2n, threads, "baseline")))
        if all(base):
            base_gap = (base[0].summary.gflops_est
                        / max(base[1].summary.gflops_est, 1e-12))
        for mech in mechs:
            fd = by.get(("fd", log2n, threads, mech))
            rm = by.get(("rmat", log2n, threads, mech))
            if fd is None or rm is None:
                continue
            gap = fd.summary.gflops_est / max(rm.summary.gflops_est, 1e-12)
            closed = ""
            if base_gap is not None and base_gap > 1.0:
                closed = f"{1.0 - (gap - 1.0) / (base_gap - 1.0):.3f}"
            lines.append(",".join([
                str(log2n), str(threads), mech,
                f"{fd.summary.gflops_est:.4g}",
                f"{rm.summary.gflops_est:.4g}",
                f"{gap:.3f}",
                f"{rm.summary.l2_mpki:.3f}",
                fd.summary.bound(), rm.summary.bound(),
                closed,
            ]))
    return "\n".join(lines)


def plan_cache_report(stats: Dict, before: Dict = None,
                      title: str = "plan cache") -> str:
    """Render one `PlanCache.stats()` snapshot as a small CSV block.

    Pass `before` (an earlier snapshot of the SAME cache) to report the
    delta window instead of lifetime totals -- the serving benchmark uses
    this to quote the measured-phase hit rate with warmup traffic
    excluded.  `hit_rate` is recomputed from the (windowed) hit/miss
    counts, and mean compile seconds from the compile totals.
    """
    s = dict(stats)
    if before is not None:
        for k in ("hits", "misses", "evictions", "compiles", "compile_s",
                  "predictor_compiles", "predictor_compile_s",
                  "oracle_compiles", "oracle_compile_s",
                  "overlays", "swaps", "delta_recompiles"):
            s[k] = s.get(k, 0) - before.get(k, 0)
    served = s.get("hits", 0) + s.get("misses", 0)
    # .get throughout: an empty/partial stats dict renders a zero row
    # instead of raising
    hit_rate = s.get("hits", 0) / served if served else 0.0
    compiles = s.get("compiles", 0)
    mean_compile = s.get("compile_s", 0.0) / compiles if compiles else 0.0
    # compile cost split by scoring mode: learned-predictor compiles are
    # microseconds, oracle (replay/analytic) compiles can be seconds --
    # one blended mean would misstate both
    pn, ps = s.get("predictor_compiles", 0), s.get("predictor_compile_s", 0.0)
    on, os_ = s.get("oracle_compiles", 0), s.get("oracle_compile_s", 0.0)
    head = ["plans", "hits", "misses", "hit_rate", "evictions",
            "compiles", "compile_s", "mean_compile_s",
            "predictor_compiles", "predictor_compile_s",
            "oracle_compiles", "oracle_compile_s",
            "overlays", "swaps", "delta_recompiles"]
    # streaming-lifecycle counters (.get: pre-streaming stats dicts and
    # snapshots recorded before the counters existed render as zeros)
    row = [s.get("plans", 0), s.get("hits", 0), s.get("misses", 0),
           hit_rate, s.get("evictions", 0), compiles,
           s.get("compile_s", 0.0), mean_compile, pn, ps, on, os_,
           s.get("overlays", 0), s.get("swaps", 0),
           s.get("delta_recompiles", 0)]
    return "\n".join([f"# {title}" + (" (windowed)" if before else ""),
                      ",".join(head), ",".join(_fmt(v) for v in row)])


def scaling_report(points: Sequence[ScalingPoint]) -> str:
    """Speedup curves from a `sweep.scaling_sweep`: one CSV row per
    (kind, size, reorder, thread-count) with speedup, parallel
    efficiency, load imbalance, per-thread miss rates (mean and worst
    thread), DRAM utilization, and whether the prefetchers survived the
    §IV-C shutoff."""
    lines = ["# multithreaded scaling (private L1/L2, shared LLC + "
             "bandwidth model)",
             ",".join(ScalingPoint.header())]
    for p in points:
        lines.append(",".join(_fmt(v) for v in p.row()))
    return "\n".join(lines)


def scaling_gap_report(points: Sequence[ScalingPoint]) -> str:
    """The paper's speedup separation, and how much of it each
    reordering strategy closes.

    Per (size, thread count), two normalizations:

        gap            = fd(none).speedup - rmat(none).speedup
        closed_r       = (rmat(r).speedup - rmat(none).speedup) / gap
        closed_gf_r    = same formula on estimated GFLOPS

    The GFLOPS column is the honest one for reorderings: RCM speeds up
    the 1-thread baseline too, so its *relative* speedup can stay flat
    (or dip) while absolute throughput at every thread count rises.
    closed = 1.0 means the reordered R-MAT runs like FD; the paper's
    headline is gap > 0 at every thread count (FD speedup strictly
    dominates R-MAT).  Closed columns are left blank when the
    denominator gap is negative or within noise (< 0.05 speedup /
    < 2 % of FD throughput) -- dividing by a near-zero gap produces
    ratios with no meaning.
    """
    by = {(p.kind, p.log2n, p.reorder, p.threads): p for p in points}
    keys = sorted({(p.log2n, p.threads) for p in points if p.threads > 1})
    reorders = []
    for p in points:
        if p.reorder not in reorders:
            reorders.append(p.reorder)
    extra = [r for r in reorders if r != "none"]
    head = (["log2n", "threads", "fd_speedup", "rmat_speedup", "gap",
             "fd_bound", "rmat_bound"]
            + [f"gap_closed_{r}" for r in extra]
            + [f"gap_closed_gflops_{r}" for r in extra])
    lines = ["# FD vs R-MAT speedup gap per reordering strategy",
             ",".join(head)]
    for (log2n, threads) in keys:
        fd = by.get(("fd", log2n, "none", threads))
        rm = by.get(("rmat", log2n, "none", threads))
        if fd is None or rm is None:
            continue
        gap = fd.speedup - rm.speedup
        gf_gap = fd.metrics.gflops_est() - rm.metrics.gflops_est()
        gap_ok = gap > 0.05
        gf_ok = gf_gap > 0.02 * fd.metrics.gflops_est()
        row = [str(log2n), str(threads), f"{fd.speedup:.3f}",
               f"{rm.speedup:.3f}", f"{gap:.3f}",
               fd.metrics.stages.bound(), rm.metrics.stages.bound()]
        closed, closed_gf = [], []
        for r in extra:
            rr = by.get(("rmat", log2n, r, threads))
            closed.append(
                "" if rr is None or not gap_ok
                else f"{(rr.speedup - rm.speedup) / gap:.3f}")
            closed_gf.append(
                "" if rr is None or not gf_ok
                else f"{(rr.metrics.gflops_est() - rm.metrics.gflops_est()) / gf_gap:.3f}")
        lines.append(",".join(row + closed + closed_gf))
    return "\n".join(lines)


def partition_gap_report(points: Sequence[ScalingPoint]) -> str:
    """What nnz-balanced (merge) partitioning buys over row-granular
    splits, per (kind, size, reorder, thread count).

    Feed it points from two `scaling_sweep` runs over the same grid --
    one with `partition='balanced'` (row blocks split on the nnz CDF:
    the best a row-granular split can do) and one with
    `partition='merge'` (equal nonzero segments that may cut mid-row:
    the segmented/merge-CSR execution).  Per cell:

        time_ratio = balanced.time / merge.time   (> 1: merge wins)
        imbalance columns show *why*: row-granular splits cannot
        balance hub rows, merge is within one nonzero of perfect.

    FD rows are the control: near-uniform row lengths mean balanced is
    already near-perfect and the ratio should sit at ~1.0; the win
    concentrates on R-MAT, whose hub rows defeat any row-granular cut.
    """
    by = {(p.kind, p.log2n, p.reorder, p.threads, p.partition): p
          for p in points}
    keys = sorted({(p.kind, p.log2n, p.reorder, p.threads)
                   for p in points if p.threads > 1})
    lines = ["# nnz-balanced (merge) vs row-granular (balanced) partitioning",
             "kind,log2n,reorder,threads,bal_imbalance,merge_imbalance,"
             "bal_time_us,merge_time_us,time_ratio"]
    for (kind, log2n, rlabel, threads) in keys:
        bal = by.get((kind, log2n, rlabel, threads, "balanced"))
        mrg = by.get((kind, log2n, rlabel, threads, "merge"))
        if bal is None or mrg is None:
            continue
        ratio = bal.metrics.time_s / max(mrg.metrics.time_s, 1e-30)
        lines.append(",".join([
            kind, str(log2n), rlabel, str(threads),
            f"{bal.imbalance:.3f}", f"{mrg.imbalance:.3f}",
            f"{bal.metrics.time_s * 1e6:.2f}",
            f"{mrg.metrics.time_s * 1e6:.2f}", f"{ratio:.3f}"]))
    return "\n".join(lines)


def graph_report(points: Sequence[GraphPoint]) -> str:
    """One CSV row per (matrix, analytic) from a `sweep.graph_sweep`:
    iteration count, cold/warm/total cycles-per-nnz, cold vs warm L2
    miss rates."""
    lines = ["# whole-analytic runs (per-iteration trace replay, warm "
             "hierarchy)", ",".join(GraphPoint.header())]
    for p in points:
        lines.append(",".join(_fmt(v) for v in p.row()))
    return "\n".join(lines)


def graph_gap_report(points: Sequence[GraphPoint]) -> str:
    """How the FD-vs-R-MAT structure gap compounds over whole analytics.

    Per (size, analytic):

        gap_cold  = rmat.cold_cycles / fd.cold_cycles    (one SpMV, cold --
                                                          the paper's view)
        gap_warm  = rmat.warm_cycles / fd.warm_cycles    (steady iteration)
        gap_total = rmat.total_cycles / fd.total_cycles  (whole analytic,
                                                          iteration counts
                                                          included)

    gap_total > gap_cold means structure hurts *more* end-to-end than the
    single-SpMV tables suggest (R-MAT's working set keeps missing while
    FD's bands stay resident between iterations, or R-MAT needs more
    iterations to converge); the ratio of the two is the compounding
    factor.

    Iteration counts from runs that hit the `max_iters` cap without
    converging are marked with `*`: their gap_total reflects the cap,
    not the analytic — raise the cap before reading that row's total.
    """
    by = {}
    for p in points:
        by[(p.kind, p.log2n, p.analytic)] = p
    keys = sorted({(p.log2n, p.analytic) for p in points})
    lines = ["# FD vs R-MAT gap on whole analytics",
             "log2n,analytic,fd_iters,rmat_iters,gap_cold,gap_warm,"
             "gap_total,compounding"]
    for (log2n, analytic) in keys:
        fd = by.get(("fd", log2n, analytic))
        rm = by.get(("rmat", log2n, analytic))
        if fd is None or rm is None:
            continue
        gap_cold = rm.cold_cycles_per_nnz / max(fd.cold_cycles_per_nnz, 1e-12)
        gap_warm = rm.warm_cycles_per_nnz / max(fd.warm_cycles_per_nnz, 1e-12)
        gap_total = (rm.total_cycles_per_nnz
                     / max(fd.total_cycles_per_nnz, 1e-12))
        lines.append(",".join([
            str(log2n), analytic,
            f"{fd.n_iters}{'' if fd.converged else '*'}",
            f"{rm.n_iters}{'' if rm.converged else '*'}",
            f"{gap_cold:.3f}", f"{gap_warm:.3f}", f"{gap_total:.3f}",
            f"{gap_total / max(gap_cold, 1e-12):.3f}"]))
    return "\n".join(lines)


def reorder_gap_report(points: Sequence[SweepPoint],
                       metric: str = "l2_mpki") -> str:
    """Fraction of the FD-vs-R-MAT first-level miss-rate gap each
    reordering strategy closes, alone and combined with each mechanism.

    Using the unreordered baseline as the gap (FD is the structured floor):

        gap      = rmat(none, baseline) - fd(none, baseline)     [mpki]
        closed   = (rmat(none, baseline) - rmat(reorder, mech)) / gap

    closed = 0 means the strategy bought nothing; 1.0 means R-MAT now
    misses like FD; > 1 means it beat the FD floor.  The simulated first
    cache level is named L2 (Sandy Bridge terms; the paper's L1 is not
    modelled), so `metric` defaults to `l2_mpki`.

    `gap_closed_gflops` applies the same formula to estimated GFLOPS;
    unlike miss counts it also credits mechanisms that change the miss
    *service time* (stream buffers serve misses near-side without
    removing them), so it is where reorder x mechanism combinations
    separate.
    """
    by = {}
    for p in points:
        by[(p.kind, p.log2n, p.threads, p.reorder, p.mechanism)] = p
    keys = sorted({(p.log2n, p.threads) for p in points})
    combos = []
    for p in points:
        if p.kind == "rmat" and (p.reorder, p.mechanism) not in combos:
            combos.append((p.reorder, p.mechanism))
    lines = ["# FD vs R-MAT miss-rate gap per reordering strategy "
             f"(metric: {metric})",
             f"log2n,threads,reorder,mechanism,fd_{metric},rmat_{metric},"
             "gap_closed,gap_closed_gflops"]
    for (log2n, threads) in keys:
        fd0 = by.get(("fd", log2n, threads, "none", "baseline"))
        rm0 = by.get(("rmat", log2n, threads, "none", "baseline"))
        if fd0 is None or rm0 is None:
            continue
        fd_val = getattr(fd0.summary, metric)
        base_val = getattr(rm0.summary, metric)
        gap = base_val - fd_val
        gf_gap = fd0.summary.gflops_est - rm0.summary.gflops_est
        for (reorder, mech) in combos:
            rm = by.get(("rmat", log2n, threads, reorder, mech))
            if rm is None:
                continue
            val = getattr(rm.summary, metric)
            closed = (base_val - val) / gap if gap > 0 else float("nan")
            gf_closed = ((rm.summary.gflops_est - rm0.summary.gflops_est)
                         / gf_gap) if gf_gap > 0 else float("nan")
            lines.append(",".join([
                str(log2n), str(threads), reorder, mech,
                f"{fd_val:.3f}", f"{val:.3f}", f"{closed:.3f}",
                f"{gf_closed:.3f}"]))
    return "\n".join(lines)
